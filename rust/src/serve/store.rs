//! Persistent spill file for the result cache: the content-addressed LRU
//! ([`super::cache::ResultCache`]) survives restarts.
//!
//! The cache key already names the computation exactly (method, canonical
//! overrides, grid, FNV-of-f32-bits), and a cached body is a pure function
//! of its key — so persistence is just "write every (key, body) insert to
//! an append-only file, replay it on boot". Format:
//!
//! ```text
//!   SSSPILL1                                  8-byte magic
//!   repeat:
//!     u32 LE  key length                      ┐
//!     u32 LE  body length                     │ 16-byte record header
//!     u64 LE  FNV-1a over key ++ body bytes   ┘
//!     key bytes (fields joined by 0x1f)
//!     body bytes (the exact serialized response)
//! ```
//!
//! Robustness contract (exercised by the tests below): a truncated or
//! corrupted file NEVER panics and never poisons the cache — read-back
//! stops at the first bad record (everything after an append-only tear is
//! untrusted), keeps the valid prefix, and truncates the tear so new
//! appends extend a clean file. Overwritten and evicted entries leave dead
//! bytes behind; when dead bytes exceed the budget
//! ([`Store::needs_compaction`]) the cache triggers [`Store::compact`],
//! which rewrites the live entries (in LRU order, so replay restores
//! recency) to a temp file and renames it into place.

use std::fs::{File, OpenOptions};
use std::io::Write;
use std::path::{Path, PathBuf};
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, Mutex, MutexGuard};

use super::cache::{fnv1a, CacheKey};

/// File magic: identifies a spill file and its format version.
pub const MAGIC: &[u8; 8] = b"SSSPILL1";

/// Fixed bytes per record before the payloads (klen + blen + checksum).
const HEADER_LEN: usize = 16;

/// Sanity caps on declared record sizes: anything larger is corruption,
/// not data (keys are short; bodies are bounded by the cache byte budget).
const MAX_KEY_LEN: usize = 1 << 20;
const MAX_BODY_LEN: usize = 1 << 28;

/// Compaction policy: rewrite once the file holds more than
/// `2 × live + slack` bytes, i.e. dead bytes exceed live + slack.
const COMPACT_SLACK: u64 = 64 * 1024;

/// Counter snapshot for `/metrics` (`cache_persist_*` family).
#[derive(Clone, Copy, Debug, Default)]
pub struct PersistView {
    pub appends: u64,
    pub replayed: u64,
    pub compactions: u64,
    pub corrupt_dropped: u64,
    pub errors: u64,
    pub file_bytes: u64,
}

/// Append-only persistence for the result cache. All mutating calls are
/// made under the cache's state lock, so the inner file mutex is
/// uncontended; it exists so `&self` methods can write.
pub struct Store {
    file: Mutex<File>,
    path: PathBuf,
    appends: AtomicU64,
    replayed: AtomicU64,
    compactions: AtomicU64,
    corrupt_dropped: AtomicU64,
    errors: AtomicU64,
    file_bytes: AtomicU64,
}

/// Serialize a key as its fields joined by the 0x1f unit separator. None
/// of the fields can contain 0x1f: method names are identifiers and the
/// config string is compact JSON (control characters are `\u`-escaped).
fn encode_key(key: &CacheKey) -> String {
    format!(
        "{}\x1f{}\x1f{}\x1f{}\x1f{}\x1f{}\x1f{}",
        key.method, key.config, key.grid.0, key.grid.1, key.data_hash, key.n, key.d
    )
}

fn decode_key(bytes: &[u8]) -> Option<CacheKey> {
    let text = std::str::from_utf8(bytes).ok()?;
    let mut parts = text.split('\x1f');
    let method = parts.next()?.to_string();
    let config = parts.next()?.to_string();
    let h = parts.next()?.parse().ok()?;
    let w = parts.next()?.parse().ok()?;
    let data_hash = parts.next()?.parse().ok()?;
    let n = parts.next()?.parse().ok()?;
    let d = parts.next()?.parse().ok()?;
    if parts.next().is_some() {
        return None;
    }
    Some(CacheKey { method, config, grid: (h, w), data_hash, n, d })
}

/// On-disk size of one record for (key, body) — the cache tracks the sum
/// over its live entries to decide when compaction pays.
pub fn record_len(key: &CacheKey, body: &str) -> u64 {
    (HEADER_LEN + encode_key(key).len() + body.len()) as u64
}

fn checksum(key: &[u8], body: &[u8]) -> u64 {
    let mut buf = Vec::with_capacity(key.len() + body.len());
    buf.extend_from_slice(key);
    buf.extend_from_slice(body);
    fnv1a(&buf)
}

fn push_record(out: &mut Vec<u8>, key: &CacheKey, body: &str) {
    let kb = encode_key(key).into_bytes();
    let bb = body.as_bytes();
    out.extend_from_slice(&(kb.len() as u32).to_le_bytes());
    out.extend_from_slice(&(bb.len() as u32).to_le_bytes());
    out.extend_from_slice(&checksum(&kb, bb).to_le_bytes());
    out.extend_from_slice(&kb);
    out.extend_from_slice(bb);
}

impl Store {
    /// Open (or create) the spill file at `path`, replaying every valid
    /// record in file order. Read-back is total: a missing file starts
    /// empty, garbage or a torn tail yields the valid prefix, and the file
    /// is truncated to that prefix so appends extend clean state.
    pub fn open(path: &Path) -> std::io::Result<(Store, Vec<(CacheKey, String)>)> {
        if let Some(parent) = path.parent() {
            if !parent.as_os_str().is_empty() {
                std::fs::create_dir_all(parent)?;
            }
        }
        let mut corrupt = 0u64;
        let mut replayed = Vec::new();
        let mut valid_end = MAGIC.len() as u64;
        match std::fs::read(path) {
            Err(e) if e.kind() == std::io::ErrorKind::NotFound => {
                std::fs::write(path, MAGIC)?;
            }
            Err(e) => return Err(e),
            Ok(bytes) => {
                if bytes.len() < MAGIC.len() || &bytes[..MAGIC.len()] != MAGIC {
                    // Not a spill file (or a torn header): start over.
                    corrupt += 1;
                    std::fs::write(path, MAGIC)?;
                } else {
                    let mut at = MAGIC.len();
                    loop {
                        if at == bytes.len() {
                            break; // clean end
                        }
                        let Some((key, body, next)) = read_record(&bytes, at) else {
                            corrupt += 1;
                            break; // torn/corrupt tail: untrusted from here
                        };
                        replayed.push((key, body));
                        at = next;
                        valid_end = at as u64;
                    }
                }
            }
        }

        let file = OpenOptions::new().append(true).open(path)?;
        file.set_len(valid_end)?;
        let store = Store {
            file: Mutex::new(file),
            path: path.to_path_buf(),
            appends: AtomicU64::new(0),
            replayed: AtomicU64::new(replayed.len() as u64),
            compactions: AtomicU64::new(0),
            corrupt_dropped: AtomicU64::new(corrupt),
            errors: AtomicU64::new(0),
            file_bytes: AtomicU64::new(valid_end),
        };
        Ok((store, replayed))
    }

    fn lock_file(&self) -> MutexGuard<'_, File> {
        // Nothing here panics while holding the lock; recover anyway.
        self.file.lock().unwrap_or_else(|p| p.into_inner())
    }

    /// Append one (key, body) record. I/O failures degrade (counted,
    /// logged) rather than fail the request — the in-memory cache still
    /// serves; only durability is lost.
    pub fn append(&self, key: &CacheKey, body: &str) {
        let mut rec = Vec::with_capacity(HEADER_LEN + body.len() + 64);
        push_record(&mut rec, key, body);
        let mut file = self.lock_file();
        match file.write_all(&rec).and_then(|()| file.flush()) {
            Ok(()) => {
                self.appends.fetch_add(1, Ordering::Relaxed);
                self.file_bytes.fetch_add(rec.len() as u64, Ordering::Relaxed);
            }
            Err(e) => {
                self.errors.fetch_add(1, Ordering::Relaxed);
                eprintln!("serve: cache spill append failed: {e}");
            }
        }
    }

    /// Whether dead bytes warrant a rewrite, given the live-record byte
    /// total the cache tracks.
    pub fn needs_compaction(&self, live_bytes: u64) -> bool {
        self.file_bytes.load(Ordering::Relaxed)
            > 2u64.saturating_mul(live_bytes).saturating_add(COMPACT_SLACK)
    }

    /// Rewrite the file to exactly `live` (LRU order: oldest first, so a
    /// future replay reconstructs recency), then atomically swap it in.
    pub fn compact(&self, live: &[(CacheKey, Arc<String>)]) {
        let mut out = Vec::with_capacity(MAGIC.len() + 1024);
        out.extend_from_slice(MAGIC);
        for (key, body) in live {
            push_record(&mut out, key, body);
        }
        let tmp = self.path.with_extension("spill-tmp");
        let mut file = self.lock_file();
        let swap = (|| -> std::io::Result<File> {
            {
                let mut t = File::create(&tmp)?;
                t.write_all(&out)?;
                t.sync_all()?;
            }
            std::fs::rename(&tmp, &self.path)?;
            OpenOptions::new().append(true).open(&self.path)
        })();
        match swap {
            Ok(fresh) => {
                *file = fresh;
                self.file_bytes.store(out.len() as u64, Ordering::Relaxed);
                self.compactions.fetch_add(1, Ordering::Relaxed);
            }
            Err(e) => {
                self.errors.fetch_add(1, Ordering::Relaxed);
                let _ = std::fs::remove_file(&tmp);
                eprintln!("serve: cache spill compaction failed: {e}");
            }
        }
    }

    pub fn view(&self) -> PersistView {
        PersistView {
            appends: self.appends.load(Ordering::Relaxed),
            replayed: self.replayed.load(Ordering::Relaxed),
            compactions: self.compactions.load(Ordering::Relaxed),
            corrupt_dropped: self.corrupt_dropped.load(Ordering::Relaxed),
            errors: self.errors.load(Ordering::Relaxed),
            file_bytes: self.file_bytes.load(Ordering::Relaxed),
        }
    }
}

/// Parse the record starting at `at`; `None` on any inconsistency
/// (truncation, oversized lengths, checksum or key-format mismatch).
fn read_record(bytes: &[u8], at: usize) -> Option<(CacheKey, String, usize)> {
    let header = bytes.get(at..at + HEADER_LEN)?;
    let klen = u32::from_le_bytes(header[0..4].try_into().ok()?) as usize;
    let blen = u32::from_le_bytes(header[4..8].try_into().ok()?) as usize;
    let want = u64::from_le_bytes(header[8..16].try_into().ok()?);
    if klen > MAX_KEY_LEN || blen > MAX_BODY_LEN {
        return None;
    }
    let kstart = at + HEADER_LEN;
    let kb = bytes.get(kstart..kstart + klen)?;
    let bb = bytes.get(kstart + klen..kstart + klen + blen)?;
    if checksum(kb, bb) != want {
        return None;
    }
    let key = decode_key(kb)?;
    let body = String::from_utf8(bb.to_vec()).ok()?;
    Some((key, body, kstart + klen + blen))
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::AtomicU64;

    fn temp_path(tag: &str) -> PathBuf {
        static C: AtomicU64 = AtomicU64::new(0);
        std::env::temp_dir().join(format!(
            "sssort-store-{}-{tag}-{}",
            std::process::id(),
            C.fetch_add(1, Ordering::Relaxed)
        ))
    }

    fn key(tag: &str, seed: u64) -> CacheKey {
        CacheKey {
            method: "softsort".into(),
            config: format!("{{\"seed\":\"{tag}\"}}"),
            grid: (4, 4),
            data_hash: seed,
            n: 16,
            d: 3,
        }
    }

    #[test]
    fn key_encoding_round_trips() {
        let k = key("a", 0xdead_beef_0042);
        assert_eq!(decode_key(encode_key(&k).as_bytes()).unwrap(), k);
        assert!(decode_key(b"too\x1ffew\x1ffields").is_none());
        assert!(decode_key(b"m\x1fc\x1f4\x1f4\x1fnope\x1f16\x1f3").is_none());
    }

    #[test]
    fn round_trip_replays_bodies_byte_identically() {
        let path = temp_path("roundtrip");
        let bodies = [r#"{"perm":[1,0]}"#, r#"{"perm":[0,1],"loss":0.125}"#, "x"];
        {
            let (store, replayed) = Store::open(&path).unwrap();
            assert!(replayed.is_empty());
            for (i, b) in bodies.iter().enumerate() {
                store.append(&key("k", i as u64), b);
            }
            assert_eq!(store.view().appends, 3);
        }
        let (store, replayed) = Store::open(&path).unwrap();
        assert_eq!(replayed.len(), 3);
        for (i, b) in bodies.iter().enumerate() {
            assert_eq!(replayed[i].0, key("k", i as u64));
            assert_eq!(replayed[i].1.as_str(), *b, "body {i} must replay byte-identically");
        }
        let v = store.view();
        assert_eq!((v.replayed, v.corrupt_dropped), (3, 0));
        let _ = std::fs::remove_file(&path);
    }

    #[test]
    fn truncated_tail_recovers_prefix_and_keeps_appending() {
        let path = temp_path("trunc");
        {
            let (store, _) = Store::open(&path).unwrap();
            store.append(&key("a", 1), "first");
            store.append(&key("b", 2), "second");
        }
        // Tear the last record mid-body.
        let len = std::fs::metadata(&path).unwrap().len();
        let f = OpenOptions::new().write(true).open(&path).unwrap();
        f.set_len(len - 3).unwrap();
        drop(f);

        let (store, replayed) = Store::open(&path).unwrap();
        assert_eq!(replayed.len(), 1, "only the intact prefix survives");
        assert_eq!(replayed[0].1, "first");
        assert_eq!(store.view().corrupt_dropped, 1);
        // The tear was truncated away; appends extend a clean file.
        store.append(&key("c", 3), "third");
        drop(store);
        let (_, replayed) = Store::open(&path).unwrap();
        let bodies: Vec<&str> = replayed.iter().map(|(_, b)| b.as_str()).collect();
        assert_eq!(bodies, ["first", "third"]);
        let _ = std::fs::remove_file(&path);
    }

    #[test]
    fn garbage_file_opens_empty_without_panicking() {
        let path = temp_path("garbage");
        std::fs::write(&path, b"this is not a spill file, just bytes").unwrap();
        let (store, replayed) = Store::open(&path).unwrap();
        assert!(replayed.is_empty());
        assert_eq!(store.view().corrupt_dropped, 1);
        store.append(&key("a", 9), "fresh");
        drop(store);
        let (_, replayed) = Store::open(&path).unwrap();
        assert_eq!(replayed.len(), 1);
        assert_eq!(replayed[0].1, "fresh");
        let _ = std::fs::remove_file(&path);
    }

    #[test]
    fn corrupt_checksum_stops_replay_at_the_bad_record() {
        let path = temp_path("checksum");
        {
            let (store, _) = Store::open(&path).unwrap();
            store.append(&key("a", 1), "alpha");
            store.append(&key("b", 2), "beta");
            store.append(&key("c", 3), "gamma");
        }
        // Flip one byte inside the second record's body ("beta" is the
        // last 4 bytes of record 2).
        let mut bytes = std::fs::read(&path).unwrap();
        let rec1_end = MAGIC.len() as u64 + record_len(&key("a", 1), "alpha");
        let in_rec2 = rec1_end as usize + HEADER_LEN + 2;
        bytes[in_rec2] ^= 0xff;
        std::fs::write(&path, &bytes).unwrap();

        let (store, replayed) = Store::open(&path).unwrap();
        // Everything after the first bad record is untrusted by design.
        assert_eq!(replayed.len(), 1);
        assert_eq!(replayed[0].1, "alpha");
        assert_eq!(store.view().corrupt_dropped, 1);
        let _ = std::fs::remove_file(&path);
    }

    #[test]
    fn compaction_rewrites_to_live_entries_only() {
        let path = temp_path("compact");
        let live: Vec<(CacheKey, Arc<String>)> = vec![
            (key("x", 10), Arc::new("ten".to_string())),
            (key("y", 11), Arc::new("eleven".to_string())),
        ];
        {
            let (store, _) = Store::open(&path).unwrap();
            for i in 0..50 {
                store.append(&key("dead", i), &"d".repeat(2048));
            }
            let before = store.view().file_bytes;
            assert!(store.needs_compaction(0));
            store.compact(&live);
            let v = store.view();
            assert_eq!(v.compactions, 1);
            assert!(v.file_bytes < before / 10, "dead bytes reclaimed");
            // Appends keep working on the swapped-in file.
            store.append(&key("z", 12), "twelve");
        }
        let (_, replayed) = Store::open(&path).unwrap();
        let bodies: Vec<&str> = replayed.iter().map(|(_, b)| b.as_str()).collect();
        assert_eq!(bodies, ["ten", "eleven", "twelve"]);
        let _ = std::fs::remove_file(&path);
    }
}
