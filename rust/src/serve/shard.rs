//! Sharded engine-host pool: hashed job affinity over K single-threaded
//! engine hosts.
//!
//! The single engine-host design (one thread, one `Engine`) is what keeps
//! serve results bit-identical to sequential `Engine::sort` — but one host
//! is also the throughput ceiling. Sorts are deterministic pure functions,
//! so running K hosts changes *which thread* computes a result, never its
//! bytes: the pool scales compute without touching the byte-identity
//! contract.
//!
//! What sharding buys beyond raw parallelism is **cache locality**. The
//! paper's N-parameter formulation keeps per-shape state tiny (an N-vector
//! of scores, not an N×N transport plan), so an `Engine` can afford to
//! keep step sessions and compiled executables memoized per `(n, d, h)`
//! shape. Routing each job by a hash of its *shape identity* — (method,
//! canonical overrides, grid) — sends repeat shapes to the same home
//! shard, whose warm `StepSession` (scratch buffers + parked worker pool)
//! and executable cache serve them without rebuild. Dataset bytes are
//! deliberately excluded from the hash: different data on the same shape
//! wants the same warm session.
//!
//! Two failure-containment mechanisms round out the pool:
//!
//! - **Work stealing** (sender side): when a job's home sub-queue is full
//!   or closed, `dispatch` walks to the next alive shard instead of
//!   failing the request — a hot shape degrades to cold-cache latency on a
//!   neighbor shard, not to a 503.
//! - **Panic isolation**: each host catches per-job panics (the job gets a
//!   500, the host survives); a host-level panic (engine construction, a
//!   bug outside the per-job guard) marks only that shard dead and closes
//!   its queue, so the router skips it — one poisoned shard degrades
//!   capacity, never kills the server.

use std::panic::{catch_unwind, AssertUnwindSafe};
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::Arc;
use std::thread::JoinHandle;
use std::time::Instant;

use crate::api::{Engine, MethodKind, MethodRegistry};
use crate::backend::pool::PoolError;
use crate::grid::GridShape;
use crate::trace;

use super::cache::fnv1a;
use super::metrics::{Metrics, ShardView};
use super::queue::{Bounded, EngineError, Job, PushError};
use super::EngineSpec;

/// Most `(n, d, h)` step sessions a shard keeps warm. Each native session
/// parks a worker pool, so warming is capped rather than unbounded; the
/// affinity hash concentrates each shape on one shard, so a small cap
/// covers a shard's working set.
const WARM_SHAPES_MAX: usize = 4;

/// Route a job to its home shard: FNV-1a over the *shape identity* —
/// method, canonical (sorted-key JSON) overrides, and grid — the exact
/// inputs that determine which memoized session/executable can serve it.
/// Dataset bytes are excluded on purpose: same shape + different data
/// should land on the same warm session.
pub fn affinity_hash(method: &str, config: &str, grid: (usize, usize)) -> u64 {
    let mut buf = Vec::with_capacity(method.len() + config.len() + 18);
    buf.extend_from_slice(method.as_bytes());
    buf.push(0x1f);
    buf.extend_from_slice(config.as_bytes());
    buf.push(0x1f);
    buf.extend_from_slice(&(grid.0 as u64).to_le_bytes());
    buf.extend_from_slice(&(grid.1 as u64).to_le_bytes());
    fnv1a(&buf)
}

/// Live per-shard counters, shared between the host thread (writer) and
/// the metrics/routing readers.
pub struct ShardStats {
    pub jobs: AtomicU64,
    pub memo_entries: AtomicU64,
    pub alive: AtomicBool,
}

impl ShardStats {
    fn new() -> Self {
        ShardStats {
            jobs: AtomicU64::new(0),
            memo_entries: AtomicU64::new(0),
            alive: AtomicBool::new(true),
        }
    }
}

struct Shard {
    queue: Arc<Bounded<Job>>,
    stats: Arc<ShardStats>,
}

/// The routing fabric: K shards, each a bounded sub-queue consumed by one
/// engine-host thread owning one `Engine`.
pub struct ShardPool {
    shards: Vec<Shard>,
}

impl ShardPool {
    /// Spawn `k` engine hosts (≥ 1). The configured total queue depth is
    /// split evenly across sub-queues so `--queue-depth` keeps meaning
    /// "jobs admitted before 503", independent of the shard count.
    pub fn start(
        spec: EngineSpec,
        k: usize,
        total_depth: usize,
        metrics: Arc<Metrics>,
    ) -> (Arc<ShardPool>, Vec<JoinHandle<()>>) {
        let k = k.max(1);
        let per_shard_depth = (total_depth / k).max(1);
        let mut shards = Vec::with_capacity(k);
        let mut hosts = Vec::with_capacity(k);
        for id in 0..k {
            let queue: Arc<Bounded<Job>> = Arc::new(Bounded::new(per_shard_depth));
            let stats = Arc::new(ShardStats::new());
            hosts.push(spawn_engine_host(
                id,
                spec.clone(),
                queue.clone(),
                metrics.clone(),
                stats.clone(),
            ));
            shards.push(Shard { queue, stats });
        }
        (Arc::new(ShardPool { shards }), hosts)
    }

    /// Enqueue a job at its home shard (`hash % k`), stealing forward to
    /// the next alive shard when the home sub-queue is full or its host is
    /// dead. Returns the shard index that accepted the job. `Full` means
    /// every alive shard was saturated; `Closed` means no shard is alive.
    pub fn dispatch(
        &self,
        hash: u64,
        job: Job,
        metrics: &Metrics,
    ) -> Result<usize, PushError<Job>> {
        let k = self.shards.len();
        let home = (hash % k as u64) as usize;
        let mut job = job;
        let mut any_alive = false;
        for step in 0..k {
            let idx = (home + step) % k;
            let shard = &self.shards[idx];
            if !shard.stats.alive.load(Ordering::SeqCst) {
                continue;
            }
            any_alive = true;
            match shard.queue.try_push(job) {
                Ok(()) => {
                    if idx != home {
                        metrics.shard_steals.fetch_add(1, Ordering::Relaxed);
                    }
                    return Ok(idx);
                }
                // The item comes back on refusal; offer it to the next
                // shard (Closed here = this host died between the alive
                // check and the push — treat like dead, keep walking).
                Err(PushError::Full(j)) | Err(PushError::Closed(j)) => job = j,
            }
        }
        if any_alive {
            Err(PushError::Full(job))
        } else {
            Err(PushError::Closed(job))
        }
    }

    /// Simulate (or react to) a shard loss: mark it dead and close its
    /// queue so the host drains in-flight jobs and exits. Routing skips it
    /// from the next `dispatch` on.
    pub fn kill(&self, idx: usize) {
        if let Some(shard) = self.shards.get(idx) {
            shard.stats.alive.store(false, Ordering::SeqCst);
            shard.queue.close();
        }
    }

    /// Close every sub-queue (graceful shutdown: pending jobs drain).
    pub fn close_all(&self) {
        for shard in &self.shards {
            shard.queue.close();
        }
    }

    pub fn shard_count(&self) -> usize {
        self.shards.len()
    }

    pub fn alive_count(&self) -> usize {
        self.shards.iter().filter(|s| s.stats.alive.load(Ordering::SeqCst)).count()
    }

    /// Sum of queued (not yet popped) jobs across shards.
    pub fn total_depth(&self) -> usize {
        self.shards.iter().map(|s| s.queue.len()).sum()
    }

    pub fn snapshots(&self) -> Vec<ShardView> {
        self.shards
            .iter()
            .enumerate()
            .map(|(id, s)| ShardView {
                id,
                alive: s.stats.alive.load(Ordering::SeqCst),
                queue_depth: s.queue.len(),
                jobs: s.stats.jobs.load(Ordering::Relaxed),
                memo_entries: s.stats.memo_entries.load(Ordering::Relaxed),
            })
            .collect()
    }
}

/// Classify an engine failure: a `PoolError` anywhere in the chain means a
/// row job panicked server-side (our bug, → 500); everything else is a
/// request problem (bad overrides, mismatched shapes, → 400).
fn engine_error(e: anyhow::Error) -> EngineError {
    let internal = e.downcast_ref::<PoolError>().is_some();
    EngineError { message: format!("{e:#}"), internal }
}

/// Keep this shard's home shapes warm: after serving a learned-method job,
/// memoize its `(n, d, h)` step session (up to [`WARM_SHAPES_MAX`]) so the
/// next job on the shape hits warm scratch and a parked worker pool. Done
/// *before* the reply is sent so the memo gauge is deterministic by the
/// time the client sees the response.
fn warm_session(
    engine: &Engine,
    registry: &MethodRegistry,
    method: &str,
    grid: GridShape,
    d: usize,
    stats: &ShardStats,
) {
    let learned = registry
        .resolve(method)
        .is_some_and(|s| matches!(s.kind, MethodKind::Learned));
    if learned && engine.session_memo_entries() < WARM_SHAPES_MAX {
        let _ = engine.step_session(grid.n(), d, grid.h);
    }
    stats.memo_entries.store(engine.session_memo_entries() as u64, Ordering::Relaxed);
}

/// Spawn one engine host: one thread, one `Engine`, jobs in sub-queue
/// order. Per-job panics are caught and answered with a 500; a host-level
/// panic marks the shard dead and closes its queue so the router stops
/// sending work here (senders whose jobs were dropped see their reply
/// channel hang up → 500).
fn spawn_engine_host(
    id: usize,
    spec: EngineSpec,
    queue: Arc<Bounded<Job>>,
    metrics: Arc<Metrics>,
    stats: Arc<ShardStats>,
) -> JoinHandle<()> {
    std::thread::Builder::new()
        .name(format!("sssort-engine-{id}"))
        .spawn(move || {
            let run = catch_unwind(AssertUnwindSafe(|| {
                host_loop(id, &spec, &queue, &metrics, &stats)
            }));
            stats.alive.store(false, Ordering::SeqCst);
            if run.is_err() {
                queue.close();
                eprintln!(
                    "serve: engine shard {id} died on a host-level panic; \
                     continuing with the remaining shards"
                );
            }
        })
        .expect("spawn engine host thread")
}

/// Feed a finished sort's convergence summary into the sliding per-method
/// windows behind `/metrics`: mean final loss, the fraction of phases the
/// acceptance gate rejected, and DPQ when the method computes one (NaN is
/// skipped inside the window).
fn note_convergence(
    metrics: &Metrics,
    method: &str,
    report: &crate::coordinator::events::RunReport,
) {
    let rejected_rate = report.rejected_phases as f64 / report.phases.max(1) as f64;
    metrics.observe_convergence(method, report.final_loss, rejected_rate, report.final_dpq);
}

/// Observe a popped job's queue wait: always into the histogram, and as a
/// `queue_wait` span when the request is traced. Returns the pop instant.
fn note_queue_wait(
    metrics: &Metrics,
    enqueued_at: Instant,
    trace_ctx: Option<trace::SpanContext>,
) -> Instant {
    let popped = Instant::now();
    let wait = popped.duration_since(enqueued_at);
    metrics.queue_wait.observe(wait.as_secs_f64());
    if let Some(parent) = trace_ctx {
        trace::record_span(parent, "queue_wait", enqueued_at, wait, &[]);
    }
    popped
}

fn host_loop(
    id: usize,
    spec: &EngineSpec,
    queue: &Bounded<Job>,
    metrics: &Metrics,
    stats: &ShardStats,
) {
    let registry = spec.registry;
    let engine = spec.build_engine();
    while let Some(job) = queue.pop() {
        metrics.engine_jobs.fetch_add(1, Ordering::Relaxed);
        stats.jobs.fetch_add(1, Ordering::Relaxed);
        match job {
            Job::Sort(j) => {
                let started = note_queue_wait(metrics, j.enqueued_at, j.trace);
                // Everything the engine records (phases, tiles, step
                // families) parents under this span; it must end before
                // the reply so the handler's `trace::finish` sees it.
                let mut jspan = trace::Span::child_of(j.trace, "engine_job");
                jspan.attr_u64("shard", id as u64);
                let cur = jspan.make_current();
                let result = catch_unwind(AssertUnwindSafe(|| {
                    engine.sort(&j.method, &j.dataset, j.grid, &j.overrides)
                }));
                let result = match result {
                    Ok(Ok(out)) => {
                        metrics.observe(&j.method, started.elapsed().as_secs_f64());
                        metrics
                            .phase_tiles
                            .fetch_add(out.report.tiles as u64, Ordering::Relaxed);
                        note_convergence(metrics, &j.method, &out.report);
                        warm_session(&engine, &registry, &j.method, j.grid, j.dataset.d, stats);
                        out.report.trace_attrs(&mut jspan);
                        Ok(out)
                    }
                    Ok(Err(e)) => Err(engine_error(e)),
                    Err(_) => Err(EngineError {
                        message: "sort panicked in the engine host".to_string(),
                        internal: true,
                    }),
                };
                drop(cur);
                jspan.end();
                let _ = j.reply.send(result);
            }
            Job::Batch(j) => {
                let started = note_queue_wait(metrics, j.enqueued_at, j.trace);
                let mut jspan = trace::Span::child_of(j.trace, "engine_job");
                jspan.attr_u64("shard", id as u64);
                jspan.attr_u64("batch", j.datasets.len() as u64);
                let cur = jspan.make_current();
                let results = catch_unwind(AssertUnwindSafe(|| {
                    engine.sort_batch(&j.method, &j.datasets, j.grid, &j.overrides)
                }));
                let results = match results {
                    Ok(rs) => {
                        // Amortize the batch wall time over its items
                        // so the histogram stays per-sort, comparable
                        // with the single-sort path.
                        let per_item = started.elapsed().as_secs_f64()
                            / j.datasets.len().max(1) as f64;
                        for _ in 0..j.datasets.len() {
                            metrics.observe(&j.method, per_item);
                        }
                        for out in rs.iter().flatten() {
                            metrics
                                .phase_tiles
                                .fetch_add(out.report.tiles as u64, Ordering::Relaxed);
                            note_convergence(metrics, &j.method, &out.report);
                        }
                        if let Some(d) = j.datasets.first().map(|ds| ds.d) {
                            warm_session(&engine, &registry, &j.method, j.grid, d, stats);
                        }
                        rs.into_iter().map(|r| r.map_err(engine_error)).collect()
                    }
                    Err(_) => (0..j.datasets.len())
                        .map(|_| {
                            Err(EngineError {
                                message: "batch sort panicked in the engine host"
                                    .to_string(),
                                internal: true,
                            })
                        })
                        .collect(),
                };
                drop(cur);
                jspan.end();
                let _ = j.reply.send(results);
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn affinity_hash_is_stable_and_shape_sensitive() {
        let h = affinity_hash("softsort", "{\"steps\":\"16\"}", (4, 4));
        assert_eq!(h, affinity_hash("softsort", "{\"steps\":\"16\"}", (4, 4)));
        assert_ne!(h, affinity_hash("softsort", "{\"steps\":\"32\"}", (4, 4)));
        assert_ne!(h, affinity_hash("softsort", "{\"steps\":\"16\"}", (2, 8)));
        assert_ne!(h, affinity_hash("sinkhorn", "{\"steps\":\"16\"}", (4, 4)));
    }

    #[test]
    fn affinity_hash_matches_the_documented_fnv_construction() {
        // Same bytes, hashed through the shared FNV-1a: the hash is a
        // wire-stable routing contract (README documents it), not an
        // implementation detail.
        let mut buf = Vec::new();
        buf.extend_from_slice(b"softsort");
        buf.push(0x1f);
        buf.extend_from_slice(b"{}");
        buf.push(0x1f);
        buf.extend_from_slice(&3u64.to_le_bytes());
        buf.extend_from_slice(&5u64.to_le_bytes());
        assert_eq!(affinity_hash("softsort", "{}", (3, 5)), fnv1a(&buf));
    }

    /// A pool whose hosts are plain echo threads (no Engine): exercises
    /// routing, stealing and kill logic without compute.
    fn echo_pool(k: usize, depth_per_shard: usize) -> (Arc<ShardPool>, Vec<JoinHandle<()>>) {
        let mut shards = Vec::new();
        let mut hosts = Vec::new();
        for _ in 0..k {
            let queue: Arc<Bounded<Job>> = Arc::new(Bounded::new(depth_per_shard));
            let stats = Arc::new(ShardStats::new());
            let (q2, s2) = (queue.clone(), stats.clone());
            hosts.push(std::thread::spawn(move || {
                while let Some(job) = q2.pop() {
                    s2.jobs.fetch_add(1, Ordering::Relaxed);
                    match job {
                        Job::Sort(j) => drop(j.reply),
                        Job::Batch(j) => drop(j.reply),
                    }
                }
                s2.alive.store(false, Ordering::SeqCst);
            }));
            shards.push(Shard { queue, stats });
        }
        (Arc::new(ShardPool { shards }), hosts)
    }

    fn sort_job() -> Job {
        let (tx, _rx) = std::sync::mpsc::channel();
        Job::Sort(super::super::queue::SortJob {
            method: "softsort".to_string(),
            dataset: crate::data::random_colors(16, 1),
            grid: GridShape::new(4, 4),
            overrides: Vec::new(),
            trace: None,
            enqueued_at: Instant::now(),
            reply: tx,
        })
    }

    #[test]
    fn dispatch_steals_to_the_next_alive_shard_when_home_is_dead() {
        let metrics = Metrics::new();
        let (pool, hosts) = echo_pool(3, 4);
        let hash = 0u64; // home = shard 0
        pool.kill(0);
        let accepted = pool.dispatch(hash, sort_job(), &metrics).ok().unwrap();
        assert_eq!(accepted, 1, "steal walks forward from the dead home");
        assert_eq!(metrics.shard_steals.load(Ordering::Relaxed), 1);
        assert_eq!(pool.alive_count(), 2);
        pool.close_all();
        for h in hosts {
            let _ = h.join();
        }
    }

    #[test]
    fn dispatch_reports_closed_only_when_no_shard_is_alive() {
        let metrics = Metrics::new();
        let (pool, hosts) = echo_pool(2, 4);
        pool.kill(0);
        pool.kill(1);
        assert!(matches!(
            pool.dispatch(0, sort_job(), &metrics),
            Err(PushError::Closed(_))
        ));
        for h in hosts {
            let _ = h.join();
        }
    }
}
