//! Bounded job queue and the engine job types.
//!
//! The HTTP worker threads never touch the [`Engine`] directly — the
//! engine's caches are deliberately single-threaded (`RefCell`/`Rc`), and
//! running K sorts truly concurrently would oversubscribe the machine
//! anyway (each sort is already row-parallel through its step session's
//! worker pool, sized by the `--threads` budget). Instead the workers fan
//! every compute request into bounded MPMC sub-queues consumed by the
//! engine-host threads in [`super::shard`], one `Engine` per host:
//! backend construction, executable caches and `(n, d, h)` step-session
//! memoization all amortize across requests, and per-shard ordering is the
//! sub-queue order, so results are bit-identical to sequential
//! `Engine::sort` calls by construction.
//!
//! Backpressure is explicit: `try_push` never blocks an accepted client on
//! a full queue — the router work-steals to a sibling shard first, and
//! only when every alive shard is saturated does the handler turn `Full`
//! into `503`.
//!
//! [`Engine`]: crate::api::Engine

use std::collections::VecDeque;
use std::sync::{mpsc, Condvar, Mutex, MutexGuard};
use std::time::Instant;

use crate::coordinator::SortOutcome;
use crate::data::Dataset;
use crate::grid::GridShape;
use crate::trace;

/// A bounded MPMC queue: blocking `pop`, non-blocking `try_push`.
pub struct Bounded<T> {
    inner: Mutex<Inner<T>>,
    not_empty: Condvar,
    cap: usize,
}

struct Inner<T> {
    q: VecDeque<T>,
    closed: bool,
}

/// Why a `try_push` was refused (the item is handed back).
pub enum PushError<T> {
    Full(T),
    Closed(T),
}

impl<T> Bounded<T> {
    pub fn new(cap: usize) -> Self {
        Bounded {
            inner: Mutex::new(Inner { q: VecDeque::new(), closed: false }),
            not_empty: Condvar::new(),
            cap: cap.max(1),
        }
    }

    /// Lock the queue state, recovering a poisoned mutex instead of
    /// propagating the panic to every later caller. The queue's invariants
    /// are a `VecDeque` and a flag — both valid whatever a panicking
    /// holder was doing — so the state is usable as-is.
    fn lock_inner(&self) -> MutexGuard<'_, Inner<T>> {
        self.inner.lock().unwrap_or_else(|poisoned| {
            self.inner.clear_poison();
            poisoned.into_inner()
        })
    }

    /// Enqueue without blocking; a full or closed queue refuses the item.
    pub fn try_push(&self, item: T) -> Result<(), PushError<T>> {
        let mut st = self.lock_inner();
        if st.closed {
            return Err(PushError::Closed(item));
        }
        if st.q.len() >= self.cap {
            return Err(PushError::Full(item));
        }
        st.q.push_back(item);
        self.not_empty.notify_one();
        Ok(())
    }

    /// Dequeue, blocking until an item arrives. Returns `None` once the
    /// queue is closed *and* drained.
    pub fn pop(&self) -> Option<T> {
        let mut st = self.lock_inner();
        loop {
            if let Some(item) = st.q.pop_front() {
                return Some(item);
            }
            if st.closed {
                return None;
            }
            st = self.not_empty.wait(st).unwrap_or_else(|poisoned| {
                self.inner.clear_poison();
                poisoned.into_inner()
            });
        }
    }

    /// Close the queue: pending items still drain, new pushes fail, and
    /// blocked `pop`s wake up.
    pub fn close(&self) {
        self.lock_inner().closed = true;
        self.not_empty.notify_all();
    }

    pub fn len(&self) -> usize {
        self.lock_inner().q.len()
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }
}

/// A compute failure, split so the HTTP layer can pick the status class:
/// request problems (bad overrides, mismatched grid) are the client's
/// fault; panics are ours.
#[derive(Debug)]
pub struct EngineError {
    pub message: String,
    pub internal: bool,
}

/// One unit of engine work.
pub enum Job {
    Sort(SortJob),
    Batch(BatchJob),
}

pub struct SortJob {
    pub method: String,
    pub dataset: Dataset,
    pub grid: GridShape,
    pub overrides: Vec<(String, String)>,
    /// Request span the engine host re-parents its spans under (`None`
    /// when the request is untraced).
    pub trace: Option<trace::SpanContext>,
    /// When the job entered the shard queue — the host measures queue
    /// wait from it (always, for `/metrics`; as a span when traced).
    pub enqueued_at: Instant,
    pub reply: mpsc::Sender<Result<SortOutcome, EngineError>>,
}

pub struct BatchJob {
    pub method: String,
    pub datasets: Vec<Dataset>,
    pub grid: GridShape,
    pub overrides: Vec<(String, String)>,
    pub trace: Option<trace::SpanContext>,
    pub enqueued_at: Instant,
    pub reply: mpsc::Sender<Vec<Result<SortOutcome, EngineError>>>,
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::Arc;

    #[test]
    fn bounded_queue_pushes_pops_and_refuses_when_full() {
        let q: Bounded<u32> = Bounded::new(2);
        assert!(q.try_push(1).is_ok());
        assert!(q.try_push(2).is_ok());
        assert!(matches!(q.try_push(3), Err(PushError::Full(3))));
        assert_eq!(q.len(), 2);
        assert_eq!(q.pop(), Some(1));
        assert!(q.try_push(3).is_ok());
        assert_eq!(q.pop(), Some(2));
        assert_eq!(q.pop(), Some(3));
        assert!(q.is_empty());
    }

    #[test]
    fn close_drains_then_wakes_blocked_pops() {
        let q: Arc<Bounded<u32>> = Arc::new(Bounded::new(4));
        q.try_push(7).ok().unwrap();
        q.close();
        assert!(matches!(q.try_push(8), Err(PushError::Closed(8))));
        assert_eq!(q.pop(), Some(7), "pending items drain after close");
        assert_eq!(q.pop(), None);
        // A pop blocked *before* close must wake up too.
        let q2: Arc<Bounded<u32>> = Arc::new(Bounded::new(4));
        let waiter = {
            let q2 = q2.clone();
            std::thread::spawn(move || q2.pop())
        };
        std::thread::sleep(std::time::Duration::from_millis(50));
        q2.close();
        assert_eq!(waiter.join().unwrap(), None);
    }

    #[test]
    fn poisoned_queue_mutex_recovers_and_keeps_serving() {
        let q: Arc<Bounded<u32>> = Arc::new(Bounded::new(4));
        q.try_push(7).ok().unwrap();
        // Poison the lock the way a buggy holder would: panic while held.
        let q2 = q.clone();
        let poisoner = std::thread::spawn(move || {
            let _guard = q2.inner.lock().unwrap();
            panic!("deliberate poison for test");
        });
        assert!(poisoner.join().is_err());
        // The queue still works: the pending item drains, pushes succeed.
        assert_eq!(q.pop(), Some(7));
        assert!(q.try_push(8).is_ok());
        assert_eq!(q.len(), 1);
    }
}
