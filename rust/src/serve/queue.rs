//! Bounded job queue and the engine host thread.
//!
//! The HTTP worker threads never touch the [`Engine`] directly — the
//! engine's caches are deliberately single-threaded (`RefCell`/`Rc`), and
//! running K sorts truly concurrently would oversubscribe the machine
//! anyway (each sort is already row-parallel through its step session's
//! worker pool, sized by the `--threads` budget). Instead the workers fan
//! every compute request into one bounded MPMC queue consumed by a single
//! **engine host** thread that owns the one shared `Engine` for the whole
//! server lifetime: backend construction, PJRT executable caches and
//! `(n, d, h)` step-session memoization all amortize across requests, and
//! cross-request ordering is the queue order, so results are bit-identical
//! to sequential `Engine::sort` calls by construction.
//!
//! Backpressure is explicit: `try_push` never blocks an accepted client on
//! a full queue — the handler turns `Full` into `503` and the client
//! retries. A panicking job (a bug, not a bad request) is caught in the
//! host and reported as an internal error; the host thread survives.

use std::collections::VecDeque;
use std::panic::{catch_unwind, AssertUnwindSafe};
use std::sync::atomic::Ordering;
use std::sync::{mpsc, Arc, Condvar, Mutex};
use std::thread::JoinHandle;
use std::time::Instant;

use crate::backend::pool::PoolError;
use crate::coordinator::SortOutcome;
use crate::data::Dataset;
use crate::grid::GridShape;

use super::metrics::Metrics;
use super::EngineSpec;

/// Classify an engine failure: a `PoolError` anywhere in the chain means a
/// row job panicked server-side (our bug, → 500); everything else is a
/// request problem (bad overrides, mismatched shapes, → 400).
fn engine_error(e: anyhow::Error) -> EngineError {
    let internal = e.downcast_ref::<PoolError>().is_some();
    EngineError { message: format!("{e:#}"), internal }
}

/// A bounded MPMC queue: blocking `pop`, non-blocking `try_push`.
pub struct Bounded<T> {
    inner: Mutex<Inner<T>>,
    not_empty: Condvar,
    cap: usize,
}

struct Inner<T> {
    q: VecDeque<T>,
    closed: bool,
}

/// Why a `try_push` was refused (the item is handed back).
pub enum PushError<T> {
    Full(T),
    Closed(T),
}

impl<T> Bounded<T> {
    pub fn new(cap: usize) -> Self {
        Bounded {
            inner: Mutex::new(Inner { q: VecDeque::new(), closed: false }),
            not_empty: Condvar::new(),
            cap: cap.max(1),
        }
    }

    /// Enqueue without blocking; a full or closed queue refuses the item.
    pub fn try_push(&self, item: T) -> Result<(), PushError<T>> {
        let mut st = self.inner.lock().expect("queue mutex poisoned");
        if st.closed {
            return Err(PushError::Closed(item));
        }
        if st.q.len() >= self.cap {
            return Err(PushError::Full(item));
        }
        st.q.push_back(item);
        self.not_empty.notify_one();
        Ok(())
    }

    /// Dequeue, blocking until an item arrives. Returns `None` once the
    /// queue is closed *and* drained.
    pub fn pop(&self) -> Option<T> {
        let mut st = self.inner.lock().expect("queue mutex poisoned");
        loop {
            if let Some(item) = st.q.pop_front() {
                return Some(item);
            }
            if st.closed {
                return None;
            }
            st = self.not_empty.wait(st).expect("queue mutex poisoned");
        }
    }

    /// Close the queue: pending items still drain, new pushes fail, and
    /// blocked `pop`s wake up.
    pub fn close(&self) {
        self.inner.lock().expect("queue mutex poisoned").closed = true;
        self.not_empty.notify_all();
    }

    pub fn len(&self) -> usize {
        self.inner.lock().expect("queue mutex poisoned").q.len()
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }
}

/// A compute failure, split so the HTTP layer can pick the status class:
/// request problems (bad overrides, mismatched grid) are the client's
/// fault; panics are ours.
#[derive(Debug)]
pub struct EngineError {
    pub message: String,
    pub internal: bool,
}

/// One unit of engine work.
pub enum Job {
    Sort(SortJob),
    Batch(BatchJob),
}

pub struct SortJob {
    pub method: String,
    pub dataset: Dataset,
    pub grid: GridShape,
    pub overrides: Vec<(String, String)>,
    pub reply: mpsc::Sender<Result<SortOutcome, EngineError>>,
}

pub struct BatchJob {
    pub method: String,
    pub datasets: Vec<Dataset>,
    pub grid: GridShape,
    pub overrides: Vec<(String, String)>,
    pub reply: mpsc::Sender<Vec<Result<SortOutcome, EngineError>>>,
}

/// Spawn the engine host: one thread, one `Engine`, jobs in queue order.
pub fn spawn_engine_host(
    spec: EngineSpec,
    queue: Arc<Bounded<Job>>,
    metrics: Arc<Metrics>,
) -> JoinHandle<()> {
    std::thread::Builder::new()
        .name("sssort-engine".to_string())
        .spawn(move || {
            let engine = spec.build_engine();
            while let Some(job) = queue.pop() {
                metrics.engine_jobs.fetch_add(1, Ordering::Relaxed);
                match job {
                    Job::Sort(j) => {
                        let started = Instant::now();
                        let result = catch_unwind(AssertUnwindSafe(|| {
                            engine.sort(&j.method, &j.dataset, j.grid, &j.overrides)
                        }));
                        let result = match result {
                            Ok(Ok(out)) => {
                                metrics.observe(&j.method, started.elapsed().as_secs_f64());
                                metrics
                                    .phase_tiles
                                    .fetch_add(out.report.tiles as u64, Ordering::Relaxed);
                                Ok(out)
                            }
                            Ok(Err(e)) => Err(engine_error(e)),
                            Err(_) => Err(EngineError {
                                message: "sort panicked in the engine host".to_string(),
                                internal: true,
                            }),
                        };
                        let _ = j.reply.send(result);
                    }
                    Job::Batch(j) => {
                        let started = Instant::now();
                        let results = catch_unwind(AssertUnwindSafe(|| {
                            engine.sort_batch(&j.method, &j.datasets, j.grid, &j.overrides)
                        }));
                        let results = match results {
                            Ok(rs) => {
                                // Amortize the batch wall time over its items
                                // so the histogram stays per-sort, comparable
                                // with the single-sort path.
                                let per_item = started.elapsed().as_secs_f64()
                                    / j.datasets.len().max(1) as f64;
                                for _ in 0..j.datasets.len() {
                                    metrics.observe(&j.method, per_item);
                                }
                                for out in rs.iter().flatten() {
                                    metrics
                                        .phase_tiles
                                        .fetch_add(out.report.tiles as u64, Ordering::Relaxed);
                                }
                                rs.into_iter().map(|r| r.map_err(engine_error)).collect()
                            }
                            Err(_) => (0..j.datasets.len())
                                .map(|_| {
                                    Err(EngineError {
                                        message: "batch sort panicked in the engine host"
                                            .to_string(),
                                        internal: true,
                                    })
                                })
                                .collect(),
                        };
                        let _ = j.reply.send(results);
                    }
                }
            }
        })
        .expect("spawn engine host thread")
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bounded_queue_pushes_pops_and_refuses_when_full() {
        let q: Bounded<u32> = Bounded::new(2);
        assert!(q.try_push(1).is_ok());
        assert!(q.try_push(2).is_ok());
        assert!(matches!(q.try_push(3), Err(PushError::Full(3))));
        assert_eq!(q.len(), 2);
        assert_eq!(q.pop(), Some(1));
        assert!(q.try_push(3).is_ok());
        assert_eq!(q.pop(), Some(2));
        assert_eq!(q.pop(), Some(3));
        assert!(q.is_empty());
    }

    #[test]
    fn close_drains_then_wakes_blocked_pops() {
        let q: Arc<Bounded<u32>> = Arc::new(Bounded::new(4));
        q.try_push(7).ok().unwrap();
        q.close();
        assert!(matches!(q.try_push(8), Err(PushError::Closed(8))));
        assert_eq!(q.pop(), Some(7), "pending items drain after close");
        assert_eq!(q.pop(), None);
        // A pop blocked *before* close must wake up too.
        let q2: Arc<Bounded<u32>> = Arc::new(Bounded::new(4));
        let waiter = {
            let q2 = q2.clone();
            std::thread::spawn(move || q2.pop())
        };
        std::thread::sleep(std::time::Duration::from_millis(50));
        q2.close();
        assert_eq!(waiter.join().unwrap(), None);
    }
}
