//! Chunked-transfer streaming for large sort responses.
//!
//! The heavyweight part of a sort response is `arranged` — the N·d
//! rearranged rows. Buffering it means a multi-megabyte `String` per
//! in-flight large-N request *and* a multi-megabyte cache entry; before
//! this module the serve layer simply defaulted `arranged` off above
//! `arranged_max_n`. Streaming closes that gap: above `stream_min_n` the
//! body is produced incrementally into HTTP/1.1 chunked transfer coding,
//! so peak memory per response is one chunk, not one body.
//!
//! The streamed bytes must equal what the buffered path would have
//! produced (the serve layer's byte-identity contract does not bend for
//! transport framing). Two facts make that cheap to guarantee:
//!
//! - `Json::Obj` is a `BTreeMap`, so object keys serialize sorted — and
//!   `"arranged"` sorts before every other response field. The streamed
//!   body is therefore `{"arranged":[...],` + the compact serialization
//!   of the remaining fields minus its leading `{`.
//! - [`write_json_num`] mirrors `Json::write`'s number formatting
//!   exactly, so each element is rendered as the buffered path would.
//!
//! Streamed responses bypass the result cache (the cache stores complete
//! bodies; a body produced incrementally is never materialized) — the
//! `X-Cache: bypass` header makes that visible.

use std::io::Write;

use super::http::{Response, StreamProducer};

/// Flush threshold: one TCP-friendly chunk per ~16 KiB of payload.
const CHUNK_BYTES: usize = 16 * 1024;

/// A `Write` adapter that frames bytes as HTTP/1.1 chunks: hex size line,
/// payload, CRLF — ending with the zero-length terminator chunk on
/// [`ChunkSink::finish`].
pub struct ChunkSink<'a, W: Write> {
    out: &'a mut W,
    buf: Vec<u8>,
}

impl<'a, W: Write> ChunkSink<'a, W> {
    pub fn new(out: &'a mut W) -> Self {
        ChunkSink { out, buf: Vec::with_capacity(CHUNK_BYTES) }
    }

    fn flush_chunk(&mut self) -> std::io::Result<()> {
        if self.buf.is_empty() {
            return Ok(());
        }
        write!(self.out, "{:x}\r\n", self.buf.len())?;
        self.out.write_all(&self.buf)?;
        self.out.write_all(b"\r\n")?;
        self.buf.clear();
        Ok(())
    }

    /// Flush the tail chunk and write the terminator. Consumes the sink:
    /// nothing can be written after the terminator.
    pub fn finish(mut self) -> std::io::Result<()> {
        self.flush_chunk()?;
        self.out.write_all(b"0\r\n\r\n")
    }
}

impl<W: Write> Write for ChunkSink<'_, W> {
    fn write(&mut self, data: &[u8]) -> std::io::Result<usize> {
        self.buf.extend_from_slice(data);
        if self.buf.len() >= CHUNK_BYTES {
            self.flush_chunk()?;
        }
        Ok(data.len())
    }

    fn flush(&mut self) -> std::io::Result<()> {
        self.flush_chunk()?;
        self.out.flush()
    }
}

/// Render one JSON number exactly as `Json::write` would (integral values
/// in f64-exact range print as integers; everything else as shortest
/// round-trip; non-finite as `null`, mirroring `json::num`). Any drift
/// here breaks the byte-identity between streamed and buffered bodies.
pub fn write_json_num(out: &mut dyn Write, n: f64) -> std::io::Result<()> {
    if !n.is_finite() {
        return out.write_all(b"null");
    }
    if n.fract() == 0.0 && n.abs() < 9e15 {
        write!(out, "{}", n as i64)
    } else {
        write!(out, "{n}")
    }
}

/// Build the streaming response for a finished sort: `rest` is the
/// buffered serialization of every field *except* `arranged` (a compact
/// JSON object), `arranged` the rows to stream. Produces bytes identical
/// to rendering the outcome with `arranged` included, because `"arranged"`
/// is the first key in sorted order.
pub fn chunked_sort_response(rest: String, arranged: Vec<f32>) -> Response {
    debug_assert!(rest.starts_with('{') && rest.len() > 2, "rest must be a non-empty object");
    let producer: StreamProducer = Box::new(move |w| {
        w.write_all(b"{\"arranged\":[")?;
        for (i, &v) in arranged.iter().enumerate() {
            if i > 0 {
                w.write_all(b",")?;
            }
            write_json_num(w, v as f64)?;
        }
        w.write_all(b"],")?;
        w.write_all(rest[1..].as_bytes())
    });
    Response::streamed(200, "application/json", producer)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::json::Json;

    /// Decode HTTP/1.1 chunked framing back to the payload bytes.
    fn dechunk(mut raw: &[u8]) -> Vec<u8> {
        let mut out = Vec::new();
        loop {
            let pos = raw.windows(2).position(|w| w == b"\r\n").expect("size line");
            let size = usize::from_str_radix(
                std::str::from_utf8(&raw[..pos]).expect("hex size"),
                16,
            )
            .expect("hex size");
            raw = &raw[pos + 2..];
            if size == 0 {
                assert_eq!(raw, b"\r\n", "terminator chunk ends the stream");
                return out;
            }
            out.extend_from_slice(&raw[..size]);
            assert_eq!(&raw[size..size + 2], b"\r\n");
            raw = &raw[size + 2..];
        }
    }

    #[test]
    fn chunk_framing_round_trips_across_flush_boundaries() {
        let payload: Vec<u8> = (0..60_000u32).map(|i| (i % 251) as u8).collect();
        let mut wire = Vec::new();
        {
            let mut sink = ChunkSink::new(&mut wire);
            // Uneven writes to cross the 16 KiB chunk boundary mid-write.
            for part in payload.chunks(7_001) {
                sink.write_all(part).unwrap();
            }
            sink.finish().unwrap();
        }
        assert_eq!(dechunk(&wire), payload);
        // An empty body is just the terminator.
        let mut wire = Vec::new();
        ChunkSink::new(&mut wire).finish().unwrap();
        assert_eq!(wire, b"0\r\n\r\n");
    }

    #[test]
    fn number_rendering_matches_the_buffered_json_writer() {
        let cases: Vec<f32> = vec![
            0.0, -0.0, 1.0, -1.0, 0.5, -0.125, 1.5e-8, 3.25e7, 16384.0, 0.1,
            f32::MIN_POSITIVE, f32::MAX,
        ];
        for v in cases {
            let mut streamed = Vec::new();
            write_json_num(&mut streamed, v as f64).unwrap();
            let buffered = Json::Num(v as f64).to_string_compact();
            assert_eq!(
                String::from_utf8(streamed).unwrap(),
                buffered,
                "value {v:?} must render identically on both paths"
            );
        }
        let mut streamed = Vec::new();
        write_json_num(&mut streamed, f64::NAN).unwrap();
        assert_eq!(streamed, b"null");
    }

    #[test]
    fn streamed_sort_body_equals_the_buffered_rendering() {
        // `rest` = the response minus `arranged`; the streamed result must
        // equal the full object with `arranged` present (BTreeMap order
        // puts it first).
        let arranged = vec![0.5f32, 2.0, -0.25];
        let rest = r#"{"method":"softsort","n":3,"perm":[2,0,1]}"#.to_string();
        let mut resp = chunked_sort_response(rest, arranged);
        let mut wire = Vec::new();
        {
            let mut sink = ChunkSink::new(&mut wire);
            let producer = resp.take_stream().expect("streamed response");
            producer(&mut sink).unwrap();
            sink.finish().unwrap();
        }
        let body = String::from_utf8(dechunk(&wire)).unwrap();
        assert_eq!(
            body,
            r#"{"arranged":[0.5,2,-0.25],"method":"softsort","n":3,"perm":[2,0,1]}"#
        );
        // And it parses back to the object the buffered path would build.
        assert!(Json::parse(&body).is_ok());
    }
}
