//! Minimal HTTP/1.1 request/response handling over `std::net` — just
//! enough protocol for the serve layer: request-line + header parsing,
//! `Content-Length` bodies (with a hard cap enforced *before* the body is
//! read), `Expect: 100-continue`, keep-alive, and always-`Content-Length`
//! responses. No chunked transfer coding, no TLS, no HTTP/2 — clients that
//! need those sit behind a real reverse proxy; this listener's job is to
//! put the Engine on a socket with zero dependencies.

use std::io::{BufRead, BufReader, Read, Write};
use std::net::TcpStream;
use std::time::Duration;

/// Cap on the request head (request line + all headers).
const MAX_HEAD_BYTES: usize = 16 * 1024;
const MAX_HEADERS: usize = 64;

/// One parsed HTTP request.
#[derive(Debug)]
pub struct Request {
    pub method: String,
    /// Path without the query string.
    pub path: String,
    /// Raw query string (after `?`), when present.
    pub query: Option<String>,
    /// `HTTP/1.1` / `HTTP/1.0`.
    pub version: String,
    /// Header pairs; names lowercased, values trimmed.
    pub headers: Vec<(String, String)>,
    pub body: Vec<u8>,
}

impl Request {
    /// Case-insensitive header lookup (first match).
    pub fn header(&self, name: &str) -> Option<&str> {
        let name = name.to_ascii_lowercase();
        self.headers.iter().find(|(k, _)| *k == name).map(|(_, v)| v.as_str())
    }

    /// Whether the client asked to keep the connection open (HTTP/1.1
    /// defaults to keep-alive, 1.0 to close).
    pub fn keep_alive(&self) -> bool {
        let conn = self.header("connection").unwrap_or("").to_ascii_lowercase();
        if self.version == "HTTP/1.0" {
            conn.contains("keep-alive")
        } else {
            !conn.contains("close")
        }
    }

    /// Value of `key` in the query string, if present.
    pub fn query_param(&self, key: &str) -> Option<&str> {
        self.query.as_deref()?.split('&').find_map(|kv| {
            let (k, v) = kv.split_once('=')?;
            (k == key).then_some(v)
        })
    }
}

/// Outcome of waiting for a request on a keep-alive connection.
#[derive(Debug)]
pub enum ReadOutcome {
    Request(Request),
    /// Clean EOF before any request byte: the peer closed the connection.
    Closed,
    /// Read timeout before any request byte: the connection is idle (the
    /// caller decides when idleness exceeds the keep-alive budget).
    Idle,
}

/// Request-reading failure, mapped to a response (or a hangup) by the
/// connection loop.
#[derive(Debug)]
pub enum HttpError {
    /// Connection-level failure (peer vanished or timed out mid-request).
    Io(std::io::Error),
    /// Protocol violation → 400.
    Malformed(String),
    /// Declared body exceeds the configured cap → 413, before reading it.
    BodyTooLarge { declared: usize, limit: usize },
}

impl std::fmt::Display for HttpError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            HttpError::Io(e) => write!(f, "i/o error: {e}"),
            HttpError::Malformed(m) => write!(f, "malformed request: {m}"),
            HttpError::BodyTooLarge { declared, limit } => {
                write!(f, "request body of {declared} bytes exceeds the {limit}-byte limit")
            }
        }
    }
}

fn is_timeout(e: &std::io::Error) -> bool {
    matches!(e.kind(), std::io::ErrorKind::WouldBlock | std::io::ErrorKind::TimedOut)
}

/// Read one `\n`-terminated line, stripping the trailing `\r\n`/`\n`.
/// Returns `(raw bytes consumed, saw a newline)`; 0 bytes = EOF. Reads at
/// most `cap` bytes — a longer line stops there instead of buffering an
/// attacker-controlled amount of memory, reported as unterminated.
fn read_line(
    reader: &mut BufReader<TcpStream>,
    buf: &mut Vec<u8>,
    cap: usize,
) -> std::io::Result<(usize, bool)> {
    buf.clear();
    let mut limited = (&mut *reader).take(cap as u64);
    let n = limited.read_until(b'\n', buf)?;
    let terminated = buf.last() == Some(&b'\n');
    while matches!(buf.last(), Some(b'\n') | Some(b'\r')) {
        buf.pop();
    }
    Ok((n, terminated))
}

/// Restores a socket's previous read timeout on drop, so the caller's
/// short idle-poll timeout survives every `read_request` exit path.
struct RestoreTimeout<'a> {
    sock: &'a TcpStream,
    prev: Option<Duration>,
}

impl Drop for RestoreTimeout<'_> {
    fn drop(&mut self) {
        let _ = self.sock.set_read_timeout(self.prev);
    }
}

/// Read the next request off a connection. `stream` is the same socket the
/// reader wraps (a `try_clone`, sharing the underlying fd): it sends the
/// `100 Continue` interim response some clients (curl) wait for before
/// uploading a body, and carries the read-timeout switch — the caller's
/// short idle-poll timeout applies while waiting for a request to *start*,
/// then `busy_timeout` governs the header/body reads so a slow client is
/// not dropped mid-upload by the idle poll.
pub fn read_request(
    reader: &mut BufReader<TcpStream>,
    stream: &TcpStream,
    max_body: usize,
    busy_timeout: Duration,
) -> Result<ReadOutcome, HttpError> {
    // -- request line ------------------------------------------------------
    let mut line = Vec::new();
    match read_line(reader, &mut line, MAX_HEAD_BYTES) {
        Ok((0, _)) => return Ok(ReadOutcome::Closed),
        Ok((n, terminated)) => {
            if !terminated && n >= MAX_HEAD_BYTES {
                return Err(HttpError::Malformed("request head too large".into()));
            }
        }
        // A timeout with nothing buffered is plain idleness; with partial
        // bytes it is a peer that stalled mid-request.
        Err(e) if is_timeout(&e) && line.is_empty() => return Ok(ReadOutcome::Idle),
        Err(e) => return Err(HttpError::Io(e)),
    }
    // A request is in flight: switch to the (longer) busy timeout until
    // this request is fully read, whatever exit path is taken.
    let _restore = RestoreTimeout {
        sock: stream,
        prev: stream.read_timeout().ok().flatten(),
    };
    let _ = stream.set_read_timeout(Some(busy_timeout));
    let mut head_bytes = line.len();
    let text = String::from_utf8_lossy(&line).into_owned();
    let mut parts = text.split_whitespace();
    let method = parts
        .next()
        .filter(|m| !m.is_empty())
        .ok_or_else(|| HttpError::Malformed("empty request line".into()))?
        .to_string();
    let target = parts
        .next()
        .ok_or_else(|| HttpError::Malformed("request line has no path".into()))?;
    let version = parts.next().unwrap_or("HTTP/1.1").to_string();
    if !version.starts_with("HTTP/") {
        return Err(HttpError::Malformed(format!("bad HTTP version '{version}'")));
    }
    let (path, query) = match target.split_once('?') {
        Some((p, q)) => (p.to_string(), Some(q.to_string())),
        None => (target.to_string(), None),
    };

    // -- headers -----------------------------------------------------------
    let mut headers: Vec<(String, String)> = Vec::new();
    loop {
        let remaining = MAX_HEAD_BYTES.saturating_sub(head_bytes).max(1);
        match read_line(reader, &mut line, remaining) {
            Ok((0, _)) => return Err(HttpError::Malformed("eof inside headers".into())),
            Ok((n, terminated)) => {
                head_bytes += n;
                if !terminated {
                    return Err(HttpError::Malformed(if n >= remaining {
                        "request head too large".into()
                    } else {
                        "eof inside headers".into()
                    }));
                }
            }
            Err(e) => return Err(HttpError::Io(e)),
        }
        if line.is_empty() {
            break;
        }
        if head_bytes > MAX_HEAD_BYTES || headers.len() >= MAX_HEADERS {
            return Err(HttpError::Malformed("request head too large".into()));
        }
        let text = String::from_utf8_lossy(&line);
        let (name, value) = text
            .split_once(':')
            .ok_or_else(|| HttpError::Malformed(format!("header without ':': '{text}'")))?;
        headers.push((name.trim().to_ascii_lowercase(), value.trim().to_string()));
    }

    let mut req =
        Request { method, path, query, version, headers, body: Vec::new() };

    // -- body --------------------------------------------------------------
    if let Some(te) = req.header("transfer-encoding") {
        if !te.eq_ignore_ascii_case("identity") {
            return Err(HttpError::Malformed(format!(
                "transfer-encoding '{te}' is not supported (send Content-Length)"
            )));
        }
    }
    let declared = match req.header("content-length") {
        None => 0,
        Some(v) => v
            .trim()
            .parse::<usize>()
            .map_err(|_| HttpError::Malformed(format!("bad Content-Length '{v}'")))?,
    };
    if declared > max_body {
        return Err(HttpError::BodyTooLarge { declared, limit: max_body });
    }
    if declared > 0 {
        if req
            .header("expect")
            .is_some_and(|e| e.to_ascii_lowercase().contains("100-continue"))
        {
            let mut writer = stream;
            writer
                .write_all(b"HTTP/1.1 100 Continue\r\n\r\n")
                .and_then(|()| writer.flush())
                .map_err(HttpError::Io)?;
        }
        let mut body = vec![0u8; declared];
        reader.read_exact(&mut body).map_err(HttpError::Io)?;
        req.body = body;
    }
    Ok(ReadOutcome::Request(req))
}

/// A body producer for streamed responses: called once with the chunk
/// sink, writes the payload incrementally.
pub type StreamProducer = Box<dyn FnOnce(&mut dyn Write) -> std::io::Result<()> + Send>;

/// An HTTP response. Buffered responses carry an explicit
/// `Content-Length`; a response with a [`StreamProducer`] attached is sent
/// with `Transfer-Encoding: chunked` instead, its body produced
/// incrementally (large `arranged` payloads never materialize in memory).
pub struct Response {
    pub status: u16,
    pub content_type: &'static str,
    pub body: Vec<u8>,
    /// Extra headers (e.g. `X-Cache`).
    pub extra_headers: Vec<(String, String)>,
    /// When set, the connection closes after this response.
    pub close: bool,
    /// When set, `body` is ignored and the producer streams the payload.
    stream: Option<StreamProducer>,
}

impl std::fmt::Debug for Response {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.debug_struct("Response")
            .field("status", &self.status)
            .field("content_type", &self.content_type)
            .field("body_len", &self.body.len())
            .field("streamed", &self.stream.is_some())
            .field("close", &self.close)
            .finish()
    }
}

impl Response {
    pub fn json(status: u16, body: String) -> Response {
        Response {
            status,
            content_type: "application/json",
            body: body.into_bytes(),
            extra_headers: Vec::new(),
            close: false,
            stream: None,
        }
    }

    pub fn text(status: u16, body: String) -> Response {
        Response {
            status,
            content_type: "text/plain; charset=utf-8",
            body: body.into_bytes(),
            extra_headers: Vec::new(),
            close: false,
            stream: None,
        }
    }

    /// A chunked-transfer response whose body comes from `producer`.
    pub fn streamed(
        status: u16,
        content_type: &'static str,
        producer: StreamProducer,
    ) -> Response {
        Response {
            status,
            content_type,
            body: Vec::new(),
            extra_headers: Vec::new(),
            close: false,
            stream: Some(producer),
        }
    }

    pub fn with_header(mut self, name: &str, value: impl Into<String>) -> Response {
        self.extra_headers.push((name.to_string(), value.into()));
        self
    }

    /// Detach the stream producer (used by `write_to`, and by tests that
    /// drive the producer against an in-memory sink).
    pub fn take_stream(&mut self) -> Option<StreamProducer> {
        self.stream.take()
    }

    pub fn write_to(&mut self, stream: &mut TcpStream) -> std::io::Result<()> {
        let producer = self.stream.take();
        let mut head = format!(
            "HTTP/1.1 {} {}\r\nContent-Type: {}\r\n",
            self.status,
            reason(self.status),
            self.content_type,
        );
        match &producer {
            None => head.push_str(&format!("Content-Length: {}\r\n", self.body.len())),
            Some(_) => head.push_str("Transfer-Encoding: chunked\r\n"),
        }
        head.push_str(&format!(
            "Connection: {}\r\n",
            if self.close { "close" } else { "keep-alive" },
        ));
        for (k, v) in &self.extra_headers {
            head.push_str(k);
            head.push_str(": ");
            head.push_str(v);
            head.push_str("\r\n");
        }
        head.push_str("\r\n");
        stream.write_all(head.as_bytes())?;
        match producer {
            None => stream.write_all(&self.body)?,
            Some(p) => {
                let mut sink = super::stream::ChunkSink::new(stream);
                p(&mut sink)?;
                sink.finish()?;
            }
        }
        stream.flush()
    }
}

/// Reason phrase for the status codes the serve layer emits.
pub fn reason(status: u16) -> &'static str {
    match status {
        200 => "OK",
        400 => "Bad Request",
        401 => "Unauthorized",
        404 => "Not Found",
        405 => "Method Not Allowed",
        408 => "Request Timeout",
        413 => "Payload Too Large",
        429 => "Too Many Requests",
        500 => "Internal Server Error",
        503 => "Service Unavailable",
        _ => "",
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::net::TcpListener;

    /// Feed raw bytes through a real loopback socket and parse them.
    fn parse_raw(raw: impl Into<Vec<u8>>) -> Result<ReadOutcome, HttpError> {
        let raw: Vec<u8> = raw.into();
        let listener = TcpListener::bind("127.0.0.1:0").unwrap();
        let addr = listener.local_addr().unwrap();
        let client = std::thread::spawn(move || {
            let mut s = TcpStream::connect(addr).unwrap();
            let _ = s.write_all(&raw);
            // Dropping the stream closes it, so EOF-sensitive cases (empty
            // input) terminate instead of waiting for more bytes. The
            // write result is ignored: the server may hang up mid-write
            // (e.g. the oversized-head rejection).
        });
        let (server, _) = listener.accept().unwrap();
        let control = server.try_clone().unwrap();
        let mut reader = BufReader::new(server);
        let out = read_request(&mut reader, &control, 1024, Duration::from_secs(5));
        client.join().unwrap();
        out
    }

    #[test]
    fn parses_a_post_with_body_and_query() {
        let out = parse_raw(
            b"POST /v1/sort?format=x HTTP/1.1\r\nHost: t\r\nContent-Length: 4\r\n\
              X-Custom: Hi\r\n\r\nabcd",
        )
        .unwrap();
        let req = match out {
            ReadOutcome::Request(r) => r,
            other => panic!("expected a request, got {other:?}"),
        };
        assert_eq!(req.method, "POST");
        assert_eq!(req.path, "/v1/sort");
        assert_eq!(req.query_param("format"), Some("x"));
        assert_eq!(req.header("x-custom"), Some("Hi"));
        assert_eq!(req.body, b"abcd");
        assert!(req.keep_alive());
    }

    #[test]
    fn connection_close_and_http10_default() {
        let out =
            parse_raw(b"GET / HTTP/1.1\r\nConnection: close\r\n\r\n").unwrap();
        if let ReadOutcome::Request(r) = out {
            assert!(!r.keep_alive());
        } else {
            panic!("expected request");
        }
        let out = parse_raw(b"GET / HTTP/1.0\r\n\r\n").unwrap();
        if let ReadOutcome::Request(r) = out {
            assert!(!r.keep_alive());
        } else {
            panic!("expected request");
        }
    }

    #[test]
    fn oversized_declared_body_is_rejected_before_reading() {
        let err = parse_raw(b"POST / HTTP/1.1\r\nContent-Length: 999999\r\n\r\n")
            .unwrap_err();
        assert!(matches!(err, HttpError::BodyTooLarge { declared: 999999, limit: 1024 }));
    }

    #[test]
    fn malformed_requests_are_typed_errors() {
        for raw in [
            b"GARBAGE\r\n\r\n".as_slice(),
            b"POST / HTTP/1.1\r\nNoColonHere\r\n\r\n".as_slice(),
            b"POST / HTTP/1.1\r\nContent-Length: nope\r\n\r\n".as_slice(),
            b"POST / HTTP/1.1\r\nTransfer-Encoding: chunked\r\n\r\n".as_slice(),
        ] {
            assert!(
                matches!(parse_raw(raw), Err(HttpError::Malformed(_))),
                "should reject {:?}",
                String::from_utf8_lossy(raw)
            );
        }
    }

    #[test]
    fn clean_eof_reads_as_closed() {
        assert!(matches!(parse_raw(b"".as_slice()), Ok(ReadOutcome::Closed)));
    }

    #[test]
    fn endless_head_line_is_capped_not_buffered() {
        // A newline-free request line (or header) must be rejected at the
        // head cap, not accumulated without bound.
        let raw = vec![b'A'; MAX_HEAD_BYTES + 4096];
        assert!(matches!(parse_raw(raw), Err(HttpError::Malformed(_))));
        let mut raw = b"GET / HTTP/1.1\r\nX-Pad: ".to_vec();
        raw.extend(std::iter::repeat(b'b').take(MAX_HEAD_BYTES + 4096));
        assert!(matches!(parse_raw(raw), Err(HttpError::Malformed(_))));
    }

    #[test]
    fn response_serializes_with_content_length() {
        let listener = TcpListener::bind("127.0.0.1:0").unwrap();
        let addr = listener.local_addr().unwrap();
        let client = std::thread::spawn(move || {
            let mut s = TcpStream::connect(addr).unwrap();
            let mut buf = Vec::new();
            s.read_to_end(&mut buf).unwrap();
            String::from_utf8(buf).unwrap()
        });
        let (mut server, _) = listener.accept().unwrap();
        let mut resp = Response::json(200, "{\"ok\":true}".to_string())
            .with_header("X-Cache", "hit");
        resp.close = true;
        resp.write_to(&mut server).unwrap();
        drop(server);
        let text = client.join().unwrap();
        assert!(text.starts_with("HTTP/1.1 200 OK\r\n"), "{text}");
        assert!(text.contains("Content-Length: 11\r\n"), "{text}");
        assert!(text.contains("X-Cache: hit\r\n"), "{text}");
        assert!(text.contains("Connection: close\r\n"), "{text}");
        assert!(text.ends_with("{\"ok\":true}"), "{text}");
    }

    #[test]
    fn streamed_response_serializes_with_chunked_framing() {
        let listener = TcpListener::bind("127.0.0.1:0").unwrap();
        let addr = listener.local_addr().unwrap();
        let client = std::thread::spawn(move || {
            let mut s = TcpStream::connect(addr).unwrap();
            let mut buf = Vec::new();
            s.read_to_end(&mut buf).unwrap();
            String::from_utf8(buf).unwrap()
        });
        let (mut server, _) = listener.accept().unwrap();
        let mut resp = Response::streamed(
            200,
            "application/json",
            Box::new(|w| {
                w.write_all(b"hello ")?;
                w.write_all(b"world")
            }),
        )
        .with_header("X-Cache", "bypass");
        resp.close = true;
        resp.write_to(&mut server).unwrap();
        drop(server);
        let text = client.join().unwrap();
        assert!(text.starts_with("HTTP/1.1 200 OK\r\n"), "{text}");
        assert!(text.contains("Transfer-Encoding: chunked\r\n"), "{text}");
        assert!(!text.contains("Content-Length"), "{text}");
        assert!(text.contains("X-Cache: bypass\r\n"), "{text}");
        // 11 bytes buffered into one chunk (0xb), then the terminator.
        assert!(text.ends_with("\r\n\r\nb\r\nhello world\r\n0\r\n\r\n"), "{text}");
    }
}
