//! `serve` — a dependency-free HTTP service layer over the [`Engine`].
//!
//! The compute spine (Sorter/registry → Engine → StepBackend →
//! StepSession/WorkerPool) was reachable only through one-shot CLI
//! invocations; this subsystem puts it on a socket so expensive learned
//! sorts (Gumbel-Sinkhorn, Kissing, ShuffleSoftSort at scale) amortize
//! across clients. Everything is `std`-only: no tokio, no hyper, no serde.
//!
//! Architecture (one [`Server`]):
//!
//! ```text
//!   N http worker threads ──► auth + rate limit (limit.rs)
//!        (http.rs)                   │
//!                                    ▼
//!                          parse → LRU result cache ──hit──► reply
//!                                     (cache.rs ⇄ store.rs spill file)
//!                                         │ miss
//!                                         ▼
//!                        affinity hash → shard router (shard.rs)
//!                            │ home shard (steal on saturation)
//!                  ┌─────────┼─────────┐
//!                  ▼         ▼         ▼
//!              sub-queue  sub-queue  sub-queue     (queue.rs)
//!                  │         │         │
//!                  ▼         ▼         ▼
//!               host 0    host 1    host K-1   — one Engine each, warm
//!             (step-session memoization + `--threads` row budget)
//! ```
//!
//! * Sorts are pure functions of `(method, canonical overrides, data,
//!   grid)`, so the cache replays the exact serialized body of the first
//!   computation — bit-identical, zero extra Engine steps (observable on
//!   `/metrics` as `cache.hits` vs `engine.jobs`). With `--cache-file`
//!   the cache spills to an append-only checksummed file and survives
//!   restarts (store.rs).
//! * Concurrency comes from the HTTP workers, in-sort row parallelism,
//!   and the `--shards` engine-host pool. Determinism is unaffected:
//!   sorts are pure, so *which* host computes a result never changes its
//!   bytes. Jobs route by a hash of (method, canonical overrides, grid)
//!   so repeat shapes land on their home shard's warm step sessions;
//!   saturation work-steals to a sibling, and a dead shard only degrades
//!   capacity (shard.rs).
//! * Large `arranged` payloads (above `stream_min_n`) stream as chunked
//!   transfer coding instead of materializing in memory (stream.rs).
//! * Shutdown is graceful: SIGINT (or [`Server::shutdown`]) flips a flag;
//!   workers stop accepting, in-flight requests finish, the sub-queues
//!   drain, the engine hosts exit.
//!
//! Endpoints: `POST /v1/sort`, `POST /v1/sort_batch`, `GET /v1/methods`
//! (registry-driven, reflects plugin methods), `GET /healthz`,
//! `GET /metrics` (JSON, or Prometheus text via `?format=prometheus` /
//! `Accept: text/plain`), `GET /v1/trace/<id>` (span tree of a recent
//! traced request; `?format=chrome` for chrome://tracing), and
//! `GET /v1/profile` (collapsed-stack profile of every head-sampled
//! request — `--trace-sample K` traces 1 in K; `?format=folded` for
//! flamegraph.pl/speedscope input). Errors are JSON bodies with matching 4xx/5xx
//! statuses. With `--auth-token` every endpoint except `/healthz`
//! requires `Authorization: Bearer <token>`; `--rate-limit` adds a
//! per-client token bucket. See README §Serving for `curl` examples.

pub mod cache;
pub mod http;
pub mod json;
pub mod limit;
pub mod metrics;
pub mod queue;
pub mod shard;
pub mod store;
pub mod stream;

use std::io::BufReader;
use std::net::{IpAddr, SocketAddr, TcpListener, TcpStream};
use std::path::Path;
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::{mpsc, Arc};
use std::thread::JoinHandle;
use std::time::{Duration, Instant};

use anyhow::{Context as _, Result};

use crate::api::{BackendChoice, Engine, MethodKind, MethodRegistry, MethodSpec, SimdChoice};
use crate::config::ServeConfig;
use crate::coordinator::SortOutcome;
use crate::data::{self, Dataset};
use crate::grid::GridShape;
use crate::trace;

use cache::{hash_rows, CacheKey, ResultCache};
use http::{HttpError, ReadOutcome, Request, Response};
use json::{arr, num, obj, Json};
use limit::RateLimiter;
use metrics::{Metrics, ServeView};
use queue::{BatchJob, EngineError, Job, PushError, SortJob};
use shard::ShardPool;
use store::Store;

/// Largest grid the service will sort (memory guard: a Gumbel-Sinkhorn
/// request is O(N²) state).
pub const MAX_N: usize = 16_384;
/// Most datasets accepted in one `/v1/sort_batch` request.
pub const MAX_BATCH: usize = 64;

/// How the engine host builds its [`Engine`] (the serve-side mirror of the
/// CLI's `--artifacts/--backend/--threads/--workers` flags).
#[derive(Clone, Debug)]
pub struct EngineSpec {
    pub artifacts_dir: String,
    pub backend: BackendChoice,
    /// Row-thread budget for step sessions (`None` = backend default).
    pub threads: Option<usize>,
    /// Step-kernel level for native step sessions (the `--simd` flag).
    pub simd: SimdChoice,
    /// `sort_batch` worker cap inside the engine host.
    pub batch_workers: Option<usize>,
    /// Method set; pass `MethodRegistry::with_methods(..)` to serve
    /// plugins — `GET /v1/methods` reflects exactly this registry.
    pub registry: MethodRegistry,
}

impl Default for EngineSpec {
    fn default() -> Self {
        EngineSpec {
            artifacts_dir: "artifacts".to_string(),
            backend: BackendChoice::Auto,
            threads: None,
            simd: SimdChoice::Auto,
            batch_workers: None,
            registry: MethodRegistry::new(),
        }
    }
}

impl EngineSpec {
    pub(crate) fn build_engine(&self) -> Engine {
        let mut b = Engine::builder(&self.artifacts_dir)
            .backend(self.backend)
            .registry(self.registry);
        if let Some(t) = self.threads {
            b = b.threads(t);
        }
        b = b.simd(self.simd);
        if let Some(w) = self.batch_workers {
            b = b.workers(w);
        }
        b.build()
    }
}

/// A client-visible failure with its HTTP status.
#[derive(Debug)]
struct ApiError {
    status: u16,
    message: String,
}

impl ApiError {
    fn bad_request(message: impl Into<String>) -> Self {
        ApiError { status: 400, message: message.into() }
    }

    fn not_found(message: impl Into<String>) -> Self {
        ApiError { status: 404, message: message.into() }
    }

    fn unavailable(message: impl Into<String>) -> Self {
        ApiError { status: 503, message: message.into() }
    }

    fn internal(message: impl Into<String>) -> Self {
        ApiError { status: 500, message: message.into() }
    }

    fn from_engine(e: EngineError) -> Self {
        if e.internal {
            ApiError::internal(e.message)
        } else {
            ApiError::bad_request(format!("sort failed: {}", e.message))
        }
    }

    fn response(&self) -> Response {
        let resp = Response::json(self.status, error_body(self.status, &self.message));
        if self.status == 401 {
            // RFC 7235: a 401 must name the expected scheme.
            resp.with_header("WWW-Authenticate", "Bearer")
        } else {
            resp
        }
    }
}

fn error_body(status: u16, message: &str) -> String {
    obj([(
        "error",
        obj([("status", Json::from(status)), ("message", Json::from(message))]),
    )])
    .to_string_compact()
}

/// Shared request-handling context.
struct Ctx {
    cfg: ServeConfig,
    registry: MethodRegistry,
    backend: BackendChoice,
    metrics: Arc<Metrics>,
    cache: Arc<ResultCache>,
    pool: Arc<ShardPool>,
    store: Option<Arc<Store>>,
    limiter: Option<RateLimiter>,
    /// Requests seen by the head-based sampler (only counted when
    /// `1 < trace_sample`); request `i` is traced iff `i % K == 0`.
    sample_counter: AtomicU64,
    /// Folded-stack profile accumulated from every sampled request's
    /// finished trace (`GET /v1/profile`).
    profile: trace::profile::Profile,
}

/// The per-request sampling decision: keep the trace unconditionally
/// (head sampler hit), trace speculatively and keep it only if the
/// request runs longer than `trace_tail_ms` (tail sampling), or don't
/// trace at all.
#[derive(Clone, Copy, PartialEq, Eq, Debug)]
enum TraceMode {
    Off,
    Head,
    Tail,
}

impl Ctx {
    /// Effective tracing switch: `trace=false` and `trace_sample=0` both
    /// mean "never trace" (no root spans, `/v1/trace` + `/v1/profile` 404).
    fn tracing_enabled(&self) -> bool {
        self.cfg.trace && self.cfg.trace_sample > 0
    }

    /// The once-per-request sampling decision, made at accept. Head: a
    /// deterministic counter (not randomness) so exactly ⌈R/K⌉ of R
    /// requests trace, starting with the first. When `trace_tail_ms > 0`,
    /// a request the head counter would skip still traces speculatively
    /// (`Tail`) — the handler keeps it only if the request turns out slow,
    /// so latency outliers are captured even at sparse head rates.
    fn sample_request(&self) -> TraceMode {
        if !self.cfg.trace {
            return TraceMode::Off;
        }
        match self.cfg.trace_sample {
            0 => TraceMode::Off,
            1 => TraceMode::Head,
            k if self.sample_counter.fetch_add(1, Ordering::Relaxed) % k == 0 => TraceMode::Head,
            _ if self.cfg.trace_tail_ms > 0 => TraceMode::Tail,
            _ => TraceMode::Off,
        }
    }
}

/// A running server; dropping it shuts it down.
pub struct Server {
    addr: SocketAddr,
    shutdown: Arc<AtomicBool>,
    workers: Vec<JoinHandle<()>>,
    pool: Arc<ShardPool>,
    hosts: Vec<JoinHandle<()>>,
}

impl Server {
    /// The bound address (resolves `:0` to the real port).
    pub fn addr(&self) -> SocketAddr {
        self.addr
    }

    /// Number of engine shards this server was started with.
    pub fn shard_count(&self) -> usize {
        self.pool.shard_count()
    }

    /// Chaos/test hook: mark shard `idx` dead and close its sub-queue, as
    /// a shard panic would. Traffic homed there steals to siblings; the
    /// server keeps answering at reduced capacity.
    pub fn kill_shard(&self, idx: usize) {
        self.pool.kill(idx);
    }

    /// Graceful stop: stop accepting, finish in-flight requests, drain the
    /// sub-queues, join every thread.
    pub fn shutdown(mut self) {
        self.stop();
    }

    fn stop(&mut self) {
        self.shutdown.store(true, Ordering::SeqCst);
        for w in self.workers.drain(..) {
            let _ = w.join();
        }
        // Workers are gone: nothing can enqueue anymore; let each engine
        // host drain what is left, then exit.
        self.pool.close_all();
        for h in self.hosts.drain(..) {
            let _ = h.join();
        }
    }
}

impl Drop for Server {
    fn drop(&mut self) {
        self.stop();
    }
}

/// Bind, spawn the engine-shard pool + HTTP workers, return immediately.
pub fn start(cfg: ServeConfig, spec: EngineSpec) -> Result<Server> {
    let listener = TcpListener::bind(&cfg.addr)
        .with_context(|| format!("binding serve address {}", cfg.addr))?;
    // Non-blocking accept so workers can observe the shutdown flag.
    listener.set_nonblocking(true)?;
    let addr = listener.local_addr()?;

    let shutdown = Arc::new(AtomicBool::new(false));
    // The flag is process-global and serve only ever *enables* it (there
    // may be other traced work in-process); per-request gating stays on
    // the sampling decision. Disabled-path cost elsewhere: one relaxed
    // load. `trace_sample=0` keeps the flag untouched so every span
    // constructor short-circuits on that single load.
    if cfg.trace && cfg.trace_sample > 0 {
        trace::enable();
        trace::set_finished_cap(cfg.trace_keep);
    }
    let metrics = Arc::new(Metrics::new());
    let mut cache = ResultCache::new(
        cfg.cache_mb.saturating_mul(1024 * 1024).max(64 * 1024),
    );
    let mut store = None;
    if let Some(path) = &cfg.cache_file {
        let (s, replayed) = Store::open(Path::new(path))
            .with_context(|| format!("opening cache spill file {path}"))?;
        let s = Arc::new(s);
        // Replay BEFORE attaching: boot records must not be re-appended.
        for (key, body) in replayed {
            cache.put(key, Arc::new(body));
        }
        cache.attach_store(s.clone());
        store = Some(s);
    }
    let cache = Arc::new(cache);

    let registry = spec.registry;
    let backend = spec.backend;
    let (pool, hosts) =
        ShardPool::start(spec, cfg.shards, cfg.queue_depth, metrics.clone());
    let limiter = (cfg.rate_limit > 0).then(|| RateLimiter::new(cfg.rate_limit));

    let ctx = Arc::new(Ctx {
        cfg: cfg.clone(),
        registry,
        backend,
        metrics,
        cache,
        pool: pool.clone(),
        store,
        limiter,
        sample_counter: AtomicU64::new(0),
        profile: trace::profile::Profile::new(),
    });
    let mut workers = Vec::with_capacity(cfg.workers.max(1));
    for i in 0..cfg.workers.max(1) {
        let listener = listener.try_clone().context("cloning serve listener")?;
        let ctx = ctx.clone();
        let shutdown = shutdown.clone();
        workers.push(
            std::thread::Builder::new()
                .name(format!("sssort-http-{i}"))
                .spawn(move || worker_loop(listener, ctx, shutdown))
                .context("spawning http worker")?,
        );
    }
    Ok(Server { addr, shutdown, workers, pool, hosts })
}

/// CLI entry point: start, print where we listen, block until SIGINT,
/// shut down gracefully.
pub fn run(cfg: ServeConfig, spec: EngineSpec) -> Result<()> {
    let workers = cfg.workers.max(1);
    let backend = spec.backend;
    let server = start(cfg, spec)?;
    println!(
        "serving on http://{} ({} http workers, {} engine shard(s), backend {}, ctrl-c to stop)",
        server.addr(),
        workers,
        server.shard_count(),
        backend
    );
    sigint::install();
    while !sigint::fired() {
        std::thread::sleep(Duration::from_millis(100));
    }
    eprintln!("SIGINT: draining and shutting down");
    server.shutdown();
    Ok(())
}

/// SIGINT → shutdown-flag plumbing, with no libc crate: `signal(2)` is
/// already linked into every unix process, so declare it ourselves. The
/// handler only stores to a static atomic (async-signal-safe).
mod sigint {
    use std::sync::atomic::{AtomicBool, Ordering};

    static FIRED: AtomicBool = AtomicBool::new(false);

    #[cfg(unix)]
    extern "C" fn on_sigint(_signum: i32) {
        FIRED.store(true, Ordering::SeqCst);
    }

    pub fn install() {
        #[cfg(unix)]
        unsafe {
            extern "C" {
                fn signal(signum: i32, handler: extern "C" fn(i32)) -> usize;
            }
            // 2 = SIGINT on every unix.
            let _ = signal(2, on_sigint);
        }
    }

    pub fn fired() -> bool {
        FIRED.load(Ordering::SeqCst)
    }
}

// ---------------------------------------------------------------------------
// Connection handling.
// ---------------------------------------------------------------------------

fn worker_loop(listener: TcpListener, ctx: Arc<Ctx>, shutdown: Arc<AtomicBool>) {
    while !shutdown.load(Ordering::SeqCst) {
        match listener.accept() {
            Ok((stream, peer)) => {
                let _ = handle_connection(stream, peer.ip(), &ctx, &shutdown);
            }
            Err(e) if e.kind() == std::io::ErrorKind::WouldBlock => {
                std::thread::sleep(Duration::from_millis(15));
            }
            Err(_) => std::thread::sleep(Duration::from_millis(15)),
        }
    }
}

fn handle_connection(
    stream: TcpStream,
    peer: IpAddr,
    ctx: &Ctx,
    shutdown: &AtomicBool,
) -> std::io::Result<()> {
    stream.set_nonblocking(false)?;
    let _ = stream.set_nodelay(true);
    // Short idle-poll read timeout so the keep-alive budget and the
    // shutdown flag are observed promptly between requests;
    // `read_request` switches to the longer busy timeout once a request
    // starts arriving (and restores this one when it is done).
    stream.set_read_timeout(Some(Duration::from_millis(250)))?;
    stream.set_write_timeout(Some(Duration::from_secs(10)))?;
    let busy_timeout = Duration::from_secs(10);
    let mut writer = stream.try_clone()?;
    let mut reader = BufReader::new(stream);
    let idle_budget = Duration::from_secs(ctx.cfg.keep_alive_secs.max(1));
    let mut idle_since = Instant::now();
    loop {
        if shutdown.load(Ordering::SeqCst) {
            return Ok(());
        }
        match http::read_request(&mut reader, &writer, ctx.cfg.max_body_bytes, busy_timeout) {
            Ok(ReadOutcome::Closed) => return Ok(()),
            Ok(ReadOutcome::Idle) => {
                if idle_since.elapsed() > idle_budget {
                    return Ok(());
                }
            }
            Ok(ReadOutcome::Request(req)) => {
                idle_since = Instant::now();
                let mut resp = handle(ctx, &req, peer);
                if !req.keep_alive() || shutdown.load(Ordering::SeqCst) {
                    resp.close = true;
                }
                resp.write_to(&mut writer)?;
                if resp.close {
                    return Ok(());
                }
            }
            Err(HttpError::Malformed(m)) => {
                // Count protocol-level failures as requests too, so
                // responses never outnumber requests_total on /metrics.
                ctx.metrics.requests.fetch_add(1, Ordering::Relaxed);
                ctx.metrics.status(400);
                let mut resp =
                    Response::json(400, error_body(400, &format!("malformed request: {m}")));
                resp.close = true;
                let _ = resp.write_to(&mut writer);
                return Ok(());
            }
            Err(HttpError::BodyTooLarge { declared, limit }) => {
                ctx.metrics.requests.fetch_add(1, Ordering::Relaxed);
                ctx.metrics.status(413);
                let mut resp = Response::json(
                    413,
                    error_body(
                        413,
                        &format!("request body of {declared} bytes exceeds the {limit}-byte limit"),
                    ),
                );
                resp.close = true;
                let _ = resp.write_to(&mut writer);
                return Ok(());
            }
            Err(HttpError::Io(_)) => return Ok(()),
        }
    }
}

// ---------------------------------------------------------------------------
// Routing + endpoints.
// ---------------------------------------------------------------------------

fn handle(ctx: &Ctx, req: &Request, peer: IpAddr) -> Response {
    ctx.metrics.requests.fetch_add(1, Ordering::Relaxed);
    // Root span of the request's trace. The trace id is always minted
    // server-side and echoed in the response's `X-Trace-Id` — sharing the
    // id namespace with clients would let two concurrent requests sending
    // the same header merge their spans into one trace (or deliberately
    // overwrite another request's finished entry). A client-supplied
    // `X-Trace-Id` rides along as a correlation attribute instead.
    // Head-based sampling decides here, once per request: unsampled
    // requests get the inert span, so every downstream instrumentation
    // point (shard_route, queue_wait, engine_job, phases, tiles, step
    // clocks) sees `None` and stays on the load-and-branch path.
    let mode = ctx.sample_request();
    let started = Instant::now();
    let mut root = if mode != TraceMode::Off {
        trace::Span::root("request")
    } else {
        trace::Span::off()
    };
    if root.is_recording() {
        if let Some(cid) = req.header("x-trace-id").and_then(trace::parse_trace_id) {
            root.attr_u64("client_trace_id", cid);
        }
    }
    let trace_id = root.ctx().map(|c| c.trace_id);
    let resp = {
        let _cur = root.make_current();
        gate(ctx, req, peer)
            .and_then(|()| route(ctx, req))
            .unwrap_or_else(|e| e.response())
    };
    ctx.metrics.status(resp.status);
    root.attr_u64("status", resp.status as u64);
    root.end();
    match trace_id {
        Some(id) => {
            // Tail-sampled requests are kept only when the root span ran
            // past the threshold; fast ones are discarded wholesale —
            // their records never reach the finished LRU, the metrics
            // histograms or the profile, and the client gets no
            // `X-Trace-Id` (the trace does not exist).
            if mode == TraceMode::Tail {
                let kept = started.elapsed().as_millis() as u64 >= ctx.cfg.trace_tail_ms;
                if !kept {
                    trace::discard(id);
                    return resp;
                }
                ctx.metrics.trace_tail_kept.fetch_add(1, Ordering::Relaxed);
            }
            // Assemble now — every span of this request has ended — fold
            // the span-derived telemetry into /metrics and the collapsed
            // stacks into the continuous profile.
            if let Some(t) = trace::finish(id) {
                ctx.metrics.observe_trace(&t);
                ctx.profile.observe(&t);
            }
            resp.with_header("X-Trace-Id", trace::format_trace_id(id))
        }
        None => resp,
    }
}

/// Listener-level admission: per-client rate limit, then bearer auth.
/// `/healthz` is exempt from both — load-balancer and orchestrator probes
/// must keep working with no credentials and at any poll frequency.
fn gate(ctx: &Ctx, req: &Request, peer: IpAddr) -> Result<(), ApiError> {
    if req.path == "/healthz" {
        return Ok(());
    }
    if let Some(limiter) = &ctx.limiter {
        if !limiter.allow(peer, Instant::now()) {
            ctx.metrics.rate_limited.fetch_add(1, Ordering::Relaxed);
            return Err(ApiError {
                status: 429,
                message: format!(
                    "rate limit exceeded ({}/s steady, 2x burst) — retry later",
                    ctx.cfg.rate_limit
                ),
            });
        }
    }
    if let Some(token) = &ctx.cfg.auth_token {
        let ok = req.header("authorization").is_some_and(|v| {
            v.trim().split_once(' ').is_some_and(|(scheme, rest)| {
                scheme.eq_ignore_ascii_case("bearer") && rest.trim() == token
            })
        });
        if !ok {
            ctx.metrics.auth_failures.fetch_add(1, Ordering::Relaxed);
            return Err(ApiError {
                status: 401,
                message: "missing or invalid bearer token".to_string(),
            });
        }
    }
    Ok(())
}

fn route(ctx: &Ctx, req: &Request) -> Result<Response, ApiError> {
    const ROUTES: &[(&str, &str)] = &[
        ("GET", "/healthz"),
        ("GET", "/v1/methods"),
        ("GET", "/metrics"),
        ("GET", "/v1/profile"),
        ("POST", "/v1/sort"),
        ("POST", "/v1/sort_batch"),
    ];
    match (req.method.as_str(), req.path.as_str()) {
        ("GET", "/healthz") => Ok(healthz(ctx)),
        ("GET", "/v1/methods") => Ok(methods(ctx)),
        ("GET", "/metrics") => Ok(metrics_view(ctx, req)),
        ("GET", "/v1/profile") => profile_view(ctx, req),
        ("POST", "/v1/sort") => sort_single(ctx, req),
        ("POST", "/v1/sort_batch") => sort_batch(ctx, req),
        (m, path) if path.starts_with("/v1/trace/") => {
            if m == "GET" {
                trace_view(ctx, req)
            } else {
                Err(ApiError {
                    status: 405,
                    message: format!("method {m} not allowed for {path} (allowed: GET)"),
                })
            }
        }
        (_, path) if ROUTES.iter().any(|(_, p)| *p == path) => {
            let allowed: Vec<&str> = ROUTES
                .iter()
                .filter(|(_, p)| *p == path)
                .map(|(m, _)| *m)
                .collect();
            Err(ApiError {
                status: 405,
                message: format!(
                    "method {} not allowed for {path} (allowed: {})",
                    req.method,
                    allowed.join(", ")
                ),
            })
        }
        (_, path) => Err(ApiError::not_found(format!("no route for {path}"))),
    }
}

fn healthz(ctx: &Ctx) -> Response {
    let shards = ctx.pool.shard_count();
    let alive = ctx.pool.alive_count();
    // uptime + build info let probes tell a fresh restart from a
    // long-running degraded host (and pin down *what* is running where).
    Response::json(
        200,
        obj([
            ("status", Json::from(if alive == shards { "ok" } else { "degraded" })),
            ("backend", Json::from(ctx.backend.name())),
            ("queue_depth", Json::from(ctx.pool.total_depth())),
            ("shards", Json::from(shards)),
            ("shards_alive", Json::from(alive)),
            ("uptime_seconds", num(ctx.metrics.uptime_seconds())),
            ("version", Json::from(env!("CARGO_PKG_VERSION"))),
            ("simd", Json::from(crate::backend::simd::detected().name())),
            ("trace_sample", Json::from(if ctx.cfg.trace { ctx.cfg.trace_sample } else { 0 })),
            ("trace_tail_ms", Json::from(if ctx.cfg.trace { ctx.cfg.trace_tail_ms } else { 0 })),
        ])
        .to_string_compact(),
    )
}

fn methods(ctx: &Ctx) -> Response {
    let list = arr(ctx.registry.specs().into_iter().map(spec_json));
    Response::json(
        200,
        obj([("default_backend", Json::from(ctx.backend.name())), ("methods", list)])
            .to_string_compact(),
    )
}

fn spec_json(s: &'static MethodSpec) -> Json {
    obj([
        ("name", Json::from(s.name)),
        ("aliases", arr(s.aliases.iter().map(|&a| Json::from(a)))),
        (
            "kind",
            Json::from(match s.kind {
                MethodKind::Learned => "learned",
                MethodKind::Heuristic => "heuristic",
            }),
        ),
        ("summary", Json::from(s.summary)),
    ])
}

/// `GET /v1/trace/<id>` — the finished span tree of a recent traced
/// request, looked up in the collector's bounded LRU. Default shape is
/// the flat span list; `?format=chrome` returns Chrome trace-event JSON
/// (load in `chrome://tracing` / Perfetto).
fn trace_view(ctx: &Ctx, req: &Request) -> Result<Response, ApiError> {
    if !ctx.tracing_enabled() {
        return Err(ApiError::not_found(
            "tracing is disabled on this server (start with trace=on and trace_sample>0)",
        ));
    }
    let rest = req.path.strip_prefix("/v1/trace/").unwrap_or("");
    let id = trace::parse_trace_id(rest).ok_or_else(|| {
        ApiError::bad_request(format!(
            "bad trace id '{rest}' (expected 1-16 hex digits, e.g. the X-Trace-Id echo)"
        ))
    })?;
    let t = trace::get(id).ok_or_else(|| {
        ApiError::not_found(format!(
            "no finished trace {} — traces live in a bounded LRU; re-send the request \
             and fetch the id echoed in its X-Trace-Id response header",
            trace::format_trace_id(id)
        ))
    })?;
    let doc = if req.query_param("format") == Some("chrome") {
        trace::chrome_trace_json(&t)
    } else {
        trace::trace_json(&t)
    };
    Ok(Response::json(200, json::to_string_pretty(&doc)))
}

/// `GET /v1/profile` — the continuous profile: collapsed stacks folded
/// from every sampled request since boot (or the last `?reset=1`).
/// `?format=folded` returns Brendan Gregg folded text (paste into
/// `flamegraph.pl` or speedscope); the default is a JSON projection with
/// per-path self/total time. `?reset=1` clears the accumulator *after*
/// rendering, so a scrape-and-reset loop never loses a window.
fn profile_view(ctx: &Ctx, req: &Request) -> Result<Response, ApiError> {
    if !ctx.tracing_enabled() {
        return Err(ApiError::not_found(
            "profiling is disabled on this server (start with trace=on and trace_sample>0)",
        ));
    }
    let resp = match req.query_param("format") {
        Some("folded") => Response::text(200, ctx.profile.folded()),
        None | Some("json") => {
            Response::json(200, json::to_string_pretty(&ctx.profile.to_json()))
        }
        Some(other) => {
            return Err(ApiError::bad_request(format!(
                "unknown profile format '{other}' (expected folded or json)"
            )))
        }
    };
    if req.query_param("reset") == Some("1") {
        ctx.profile.reset();
    }
    Ok(resp)
}

fn metrics_view(ctx: &Ctx, req: &Request) -> Response {
    let (entries, bytes) = ctx.cache.stats();
    let view = ServeView {
        cache_entries: entries,
        cache_bytes: bytes,
        queue_depth: ctx.pool.total_depth(),
        shards: ctx.pool.snapshots(),
        persist: ctx.store.as_ref().map(|s| s.view()),
        trace_keep: ctx.cfg.trace_keep as u64,
        trace_evictions: trace::finished_evictions(),
    };
    let prometheus = req.query_param("format") == Some("prometheus")
        || req.header("accept").is_some_and(|a| a.contains("text/plain"));
    if prometheus {
        Response::text(200, ctx.metrics.to_prometheus(&view))
    } else {
        Response::json(200, json::to_string_pretty(&ctx.metrics.to_json(&view)))
    }
}

// ---------------------------------------------------------------------------
// Sort request parsing.
// ---------------------------------------------------------------------------

/// A validated sort request: everything the engine host needs, plus the
/// canonical cache-key material.
struct SortRequest {
    method: &'static str,
    grid: GridShape,
    overrides: Vec<(String, String)>,
    /// Canonical serialization of overrides + backend + response shape
    /// (cache-key part — bodies with and without `arranged` must cache
    /// separately).
    config: String,
    datasets: Vec<Dataset>,
    /// Whether response bodies carry the arranged rows. Resolved here:
    /// explicit `"include_arranged"` wins, otherwise on iff
    /// `n <= cfg.arranged_max_n` (large-N responses stay lightweight by
    /// default — ROADMAP "streaming/chunked responses", cheap half).
    include_arranged: bool,
    /// Opt-in convergence report (`"include_report": true`): wall time,
    /// rejected phases, extension count and tile count ride along in the
    /// body. Off by default — it is run telemetry, not sort output.
    include_report: bool,
}

impl SortRequest {
    /// Home-shard routing hash: method + canonical config + grid shape,
    /// deliberately *excluding* dataset bytes — two sorts of the same
    /// shape want the same shard's warm step session regardless of data.
    fn shard_hash(&self) -> u64 {
        shard::affinity_hash(self.method, &self.config, (self.grid.h, self.grid.w))
    }

    fn cache_key(&self, ds: &Dataset) -> CacheKey {
        CacheKey {
            method: self.method.to_string(),
            config: self.config.clone(),
            grid: (self.grid.h, self.grid.w),
            data_hash: hash_rows(&ds.rows),
            n: ds.n,
            d: ds.d,
        }
    }
}

fn parse_body(body: &[u8]) -> Result<Json, ApiError> {
    let text = std::str::from_utf8(body)
        .map_err(|_| ApiError::bad_request("request body is not UTF-8"))?;
    if text.trim().is_empty() {
        return Err(ApiError::bad_request("empty body; expected a JSON object"));
    }
    Json::parse(text).map_err(|e| ApiError::bad_request(format!("malformed JSON: {e}")))
}

fn parse_grid_field(v: Option<&Json>) -> Result<GridShape, ApiError> {
    let v = v.ok_or_else(|| {
        ApiError::bad_request("missing 'grid' (either \"HxW\" or {\"h\":..,\"w\":..})")
    })?;
    let (h, w) = match v {
        Json::Str(s) => crate::cli::parse_grid(s)
            .map_err(|e| ApiError::bad_request(format!("bad grid '{s}': {e:#}")))?,
        Json::Obj(_) => {
            let h = v.get("h").and_then(Json::as_usize);
            let w = v.get("w").and_then(Json::as_usize);
            match (h, w) {
                (Some(h), Some(w)) => (h, w),
                _ => {
                    return Err(ApiError::bad_request(
                        "grid object needs integer 'h' and 'w'",
                    ))
                }
            }
        }
        _ => return Err(ApiError::bad_request("grid must be \"HxW\" or {\"h\":..,\"w\":..}")),
    };
    if h == 0 || w == 0 {
        return Err(ApiError::bad_request("grid sides must be >= 1"));
    }
    // checked_mul: a wrap here (h, w near usize::MAX pass the per-side
    // checks) would sail through the cap and wedge the engine host.
    match h.checked_mul(w) {
        Some(n) if n <= MAX_N => Ok(GridShape::new(h, w)),
        _ => Err(ApiError::bad_request(format!(
            "grid {h}x{w} exceeds the serve cap of {MAX_N} items"
        ))),
    }
}

/// Stringify one scalar override value with the CLI's `k=v` conventions.
fn override_value(k: &str, v: &Json) -> Result<String, ApiError> {
    match v {
        Json::Str(s) => Ok(s.clone()),
        Json::Bool(b) => Ok(b.to_string()),
        Json::Num(_) => Ok(v.to_string_compact()),
        _ => Err(ApiError::bad_request(format!(
            "override '{k}' must be a scalar (string, number or bool)"
        ))),
    }
}

fn parse_sort_request(ctx: &Ctx, body: &[u8], batch: bool) -> Result<SortRequest, ApiError> {
    let j = parse_body(body)?;
    if !matches!(j, Json::Obj(_)) {
        return Err(ApiError::bad_request("request body must be a JSON object"));
    }

    let method_name = j
        .get("method")
        .and_then(Json::as_str)
        .ok_or_else(|| ApiError::bad_request("missing 'method' (string)"))?;
    let spec = ctx.registry.resolve(method_name).ok_or_else(|| {
        ApiError::not_found(format!(
            "unknown method '{method_name}' — available: {}",
            ctx.registry.names().join(", ")
        ))
    })?;

    let grid = parse_grid_field(j.get("grid"))?;

    // Overrides arrive as a JSON object: unique keys, canonical (sorted)
    // order — exactly what the cache key needs.
    let mut overrides: Vec<(String, String)> = Vec::new();
    if let Some(ov) = j.get("overrides") {
        let Json::Obj(m) = ov else {
            return Err(ApiError::bad_request("'overrides' must be an object of scalars"));
        };
        for (k, v) in m {
            overrides.push((k.clone(), override_value(k, v)?));
        }
    }
    if let Some(b) = j.get("backend") {
        let s = b
            .as_str()
            .ok_or_else(|| ApiError::bad_request("'backend' must be a string"))?;
        BackendChoice::parse(s)
            .map_err(|e| ApiError::bad_request(format!("{e:#}")))?;
        overrides.push(("backend".to_string(), s.to_ascii_lowercase()));
    }
    let include_arranged = match j.get("include_arranged") {
        None => grid.n() <= ctx.cfg.arranged_max_n,
        Some(v) => v.as_bool().ok_or_else(|| {
            ApiError::bad_request("'include_arranged' must be a boolean")
        })?,
    };
    let include_report = match j.get("include_report") {
        None => false,
        Some(v) => v.as_bool().ok_or_else(|| {
            ApiError::bad_request("'include_report' must be a boolean")
        })?,
    };
    // The resolved flags join the canonical config so the cache never
    // replays a body of the wrong shape for this request.
    let config = obj(overrides
        .iter()
        .map(|(k, v)| (k.clone(), Json::from(v.as_str())))
        .chain([
            ("include_arranged".to_string(), Json::from(include_arranged)),
            ("include_report".to_string(), Json::from(include_report)),
        ]))
    .to_string_compact();

    // Datasets.
    let mut datasets = Vec::new();
    if batch {
        let items = j
            .get("datasets")
            .and_then(Json::as_arr)
            .ok_or_else(|| ApiError::bad_request("missing 'datasets' (array) for sort_batch"))?;
        if items.is_empty() {
            return Err(ApiError::bad_request("'datasets' must not be empty"));
        }
        if items.len() > MAX_BATCH {
            return Err(ApiError::bad_request(format!(
                "'datasets' has {} items; the serve cap is {MAX_BATCH}",
                items.len()
            )));
        }
        for (i, item) in items.iter().enumerate() {
            datasets.push(dataset_from_json(item, grid).map_err(|e| ApiError {
                status: e.status,
                message: format!("datasets[{i}]: {}", e.message),
            })?);
        }
    } else {
        datasets.push(dataset_from_json(&j, grid)?);
    }

    Ok(SortRequest {
        method: spec.name,
        grid,
        overrides,
        config,
        datasets,
        include_arranged,
        include_report,
    })
}

/// An optional non-negative-integer field of a dataset spec: absent is
/// fine (the caller defaults it), present-but-wrong-typed is a 400 — a
/// silent default would compute (and cache) a different dataset than the
/// client asked for.
fn spec_usize(spec: &Json, key: &str) -> Result<Option<usize>, ApiError> {
    match spec.get(key) {
        None => Ok(None),
        Some(v) => v.as_usize().map(Some).ok_or_else(|| {
            ApiError::bad_request(format!(
                "dataset field '{key}' must be a non-negative integer"
            ))
        }),
    }
}

/// Build the dataset for one request item: either inline `data` or a
/// server-side generated `dataset` spec (hashable either way).
fn dataset_from_json(item: &Json, grid: GridShape) -> Result<Dataset, ApiError> {
    let n = grid.n();
    if let Some(spec) = item.get("dataset") {
        let kind = spec
            .get("kind")
            .and_then(Json::as_str)
            .ok_or_else(|| ApiError::bad_request("dataset spec needs 'kind' (colors|features)"))?;
        let seed = spec_usize(spec, "seed")?.unwrap_or(42) as u64;
        let spec_n = spec_usize(spec, "n")?.unwrap_or(n);
        if spec_n != n {
            return Err(ApiError::bad_request(format!(
                "dataset n={spec_n} does not match grid {}x{} (= {n} items)",
                grid.h, grid.w
            )));
        }
        match kind {
            "colors" => Ok(data::random_colors(n, seed)),
            "features" => {
                let d = spec_usize(spec, "d")?.unwrap_or(50);
                let clusters = spec_usize(spec, "clusters")?.unwrap_or(16);
                let spread = match spec.get("spread") {
                    None => 0.06f32,
                    Some(v) => {
                        let f = v.as_f64().filter(|f| f.is_finite() && *f >= 0.0).ok_or_else(
                            || {
                                ApiError::bad_request(
                                    "dataset field 'spread' must be a non-negative number",
                                )
                            },
                        )?;
                        f as f32
                    }
                };
                if d == 0 || d > 1024 || clusters == 0 {
                    return Err(ApiError::bad_request(
                        "features spec needs 1 <= d <= 1024 and clusters >= 1",
                    ));
                }
                Ok(data::clustered_features(n, d, clusters, spread, seed))
            }
            other => Err(ApiError::bad_request(format!(
                "unknown dataset kind '{other}' (expected colors or features)"
            ))),
        }
    } else if let Some(d) = item.get("data") {
        let (rows, dim) = match d {
            // Nested rows: [[r,g,b], ...] — d inferred from the first row.
            Json::Arr(rows_json) => {
                let first = rows_json
                    .first()
                    .and_then(Json::as_arr)
                    .ok_or_else(|| ApiError::bad_request("'data' rows must be number arrays"))?;
                let dim = first.len();
                if dim == 0 {
                    return Err(ApiError::bad_request("'data' rows must not be empty"));
                }
                let mut rows = Vec::with_capacity(rows_json.len() * dim);
                for (i, row) in rows_json.iter().enumerate() {
                    let row = row.as_arr().ok_or_else(|| {
                        ApiError::bad_request(format!("data[{i}] is not an array"))
                    })?;
                    if row.len() != dim {
                        return Err(ApiError::bad_request(format!(
                            "data[{i}] has {} values, expected {dim}",
                            row.len()
                        )));
                    }
                    for v in row {
                        rows.push(json_f32(v, i)?);
                    }
                }
                (rows, dim)
            }
            // Flat object: {"rows": [..], "d": 3}.
            Json::Obj(_) => {
                let dim = d
                    .get("d")
                    .and_then(Json::as_usize)
                    .ok_or_else(|| ApiError::bad_request("flat 'data' needs integer 'd'"))?;
                let flat = d
                    .get("rows")
                    .and_then(Json::as_arr)
                    .ok_or_else(|| ApiError::bad_request("flat 'data' needs 'rows' (array)"))?;
                if dim == 0 {
                    return Err(ApiError::bad_request("'d' must be >= 1"));
                }
                let mut rows = Vec::with_capacity(flat.len());
                for (i, v) in flat.iter().enumerate() {
                    rows.push(json_f32(v, i / dim)?);
                }
                (rows, dim)
            }
            _ => {
                return Err(ApiError::bad_request(
                    "'data' must be an array of rows or {\"rows\":[..],\"d\":..}",
                ))
            }
        };
        if rows.len() != n * dim {
            return Err(ApiError::bad_request(format!(
                "data has {} values ({} rows of d={dim}); grid {}x{} needs {n} rows",
                rows.len(),
                rows.len() / dim.max(1),
                grid.h,
                grid.w
            )));
        }
        Ok(Dataset { name: format!("inline{n}x{dim}"), n, d: dim, rows, labels: None })
    } else {
        Err(ApiError::bad_request(
            "missing 'data' (inline rows) or 'dataset' (generator spec)",
        ))
    }
}

fn json_f32(v: &Json, row: usize) -> Result<f32, ApiError> {
    let f = v
        .as_f64()
        .ok_or_else(|| ApiError::bad_request(format!("data row {row} has a non-number value")))?;
    // Check finiteness *after* the cast: a finite f64 beyond f32 range
    // (1e300) would otherwise smuggle an infinity into the kernels.
    let v = f as f32;
    if !v.is_finite() {
        return Err(ApiError::bad_request(format!(
            "data row {row} has a value outside the finite f32 range"
        )));
    }
    Ok(v)
}

// ---------------------------------------------------------------------------
// Sort endpoints.
// ---------------------------------------------------------------------------

/// Serialize one finished sort. The body is the cache payload, so it must
/// be a pure function of the computation *and the request's resolved
/// response shape* (no timestamps beyond the run's own wall time, no cache
/// status — that goes in the `X-Cache` header). `include_arranged` gates
/// the N·d arranged rows, the heavyweight part of large-N bodies.
fn render_outcome(
    method: &str,
    g: GridShape,
    ds: &Dataset,
    out: &SortOutcome,
    include_arranged: bool,
    include_report: bool,
) -> String {
    let mut fields = vec![
        ("method", Json::from(method)),
        ("grid", obj([("h", Json::from(g.h)), ("w", Json::from(g.w))])),
        ("n", Json::from(ds.n)),
        ("d", Json::from(ds.d)),
        ("perm", arr(out.perm.as_slice().iter().map(|&i| Json::from(i)))),
        ("dpq16", num(out.report.final_dpq)),
        ("loss", num(out.report.final_loss)),
        ("steps", Json::from(out.report.steps)),
        ("repaired", Json::from(out.report.repaired)),
        ("tiles", Json::from(out.report.tiles)),
        ("wall_secs", num(out.report.wall_secs)),
    ];
    if include_report {
        fields.push((
            "report",
            obj([
                ("wall_secs", num(out.report.wall_secs)),
                ("rejected_phases", Json::from(out.report.rejected_phases)),
                ("extensions", Json::from(out.report.extensions)),
                ("tiles", Json::from(out.report.tiles)),
                ("tile_plan", Json::from(out.report.tile_plan.as_str())),
                ("notes", arr(out.report.notes.iter().map(|n| Json::from(n.as_str())))),
            ]),
        ));
    }
    if include_arranged {
        fields.push((
            "arranged",
            arr(out.arranged.iter().map(|&v| num(v as f64))),
        ));
    }
    obj(fields).to_string_compact()
}

fn enqueue(ctx: &Ctx, hash: u64, job: Job) -> Result<(), ApiError> {
    // shard_route span: where the affinity hash homed the job, which
    // shard actually accepted it, and whether that was a steal.
    let mut span = trace::Span::child("shard_route");
    if span.is_recording() {
        let k = ctx.pool.shard_count().max(1) as u64;
        span.attr_u64("home", hash % k);
    }
    match ctx.pool.dispatch(hash, job, &ctx.metrics) {
        Ok(idx) => {
            if span.is_recording() {
                let k = ctx.pool.shard_count().max(1) as u64;
                span.attr_u64("shard", idx as u64);
                span.attr_u64("stolen", (idx as u64 != hash % k) as u64);
            }
            Ok(())
        }
        Err(e) => Err(match e {
            PushError::Full(_) => {
                // dispatch already walked every alive shard; all are saturated.
                ctx.metrics.queue_rejections.fetch_add(1, Ordering::Relaxed);
                ApiError::unavailable("every engine shard queue is full — retry shortly")
            }
            PushError::Closed(_) => {
                ApiError::unavailable("no engine shard is available (shutting down)")
            }
        }),
    }
}

fn sort_single(ctx: &Ctx, req: &Request) -> Result<Response, ApiError> {
    let parsed = parse_sort_request(ctx, &req.body, false)?;
    let ds = &parsed.datasets[0];

    // Large arranged payloads stream as chunked transfer coding instead of
    // materializing (and caching) a multi-megabyte body. The streamed
    // bytes equal the buffered rendering (see stream.rs), but the cache
    // never sees them — hence X-Cache: bypass.
    if parsed.include_arranged && ds.n > ctx.cfg.stream_min_n {
        let (tx, rx) = mpsc::channel();
        enqueue(
            ctx,
            parsed.shard_hash(),
            Job::Sort(SortJob {
                method: parsed.method.to_string(),
                dataset: ds.clone(),
                grid: parsed.grid,
                overrides: parsed.overrides.clone(),
                trace: trace::current(),
                enqueued_at: Instant::now(),
                reply: tx,
            }),
        )?;
        let outcome = rx
            .recv()
            .map_err(|_| ApiError::internal("engine host exited before replying"))?
            .map_err(ApiError::from_engine)?;
        let rest = render_outcome(
            parsed.method,
            parsed.grid,
            ds,
            &outcome,
            false,
            parsed.include_report,
        );
        return Ok(stream::chunked_sort_response(rest, outcome.arranged)
            .with_header("X-Cache", "bypass"));
    }

    let key = parsed.cache_key(ds);
    if let Some(body) = ctx.cache.get(&key) {
        ctx.metrics.cache_hits.fetch_add(1, Ordering::Relaxed);
        return Ok(Response::json(200, (*body).clone()).with_header("X-Cache", "hit"));
    }
    ctx.metrics.cache_misses.fetch_add(1, Ordering::Relaxed);

    let (tx, rx) = mpsc::channel();
    enqueue(
        ctx,
        parsed.shard_hash(),
        Job::Sort(SortJob {
            method: parsed.method.to_string(),
            dataset: ds.clone(),
            grid: parsed.grid,
            overrides: parsed.overrides.clone(),
            trace: trace::current(),
            enqueued_at: Instant::now(),
            reply: tx,
        }),
    )?;
    let outcome = rx
        .recv()
        .map_err(|_| ApiError::internal("engine host exited before replying"))?
        .map_err(ApiError::from_engine)?;
    // get_or_put: if an identical concurrent miss beat us to the insert,
    // serve its body so every response for this key is byte-identical.
    let rendered = render_outcome(
        parsed.method,
        parsed.grid,
        ds,
        &outcome,
        parsed.include_arranged,
        parsed.include_report,
    );
    let body = ctx.cache.get_or_put(key, Arc::new(rendered));
    Ok(Response::json(200, (*body).clone()).with_header("X-Cache", "miss"))
}

fn sort_batch(ctx: &Ctx, req: &Request) -> Result<Response, ApiError> {
    let parsed = parse_sort_request(ctx, &req.body, true)?;
    let m = parsed.datasets.len();

    // Per-item cache check; only the misses travel to the engine host
    // (as ONE batch job, so `Engine::sort_batch` can fan them out).
    let keys: Vec<CacheKey> = parsed.datasets.iter().map(|ds| parsed.cache_key(ds)).collect();
    let mut bodies: Vec<Option<Arc<String>>> = Vec::with_capacity(m);
    let mut miss_idx: Vec<usize> = Vec::new();
    for (i, key) in keys.iter().enumerate() {
        match ctx.cache.get(key) {
            Some(body) => {
                ctx.metrics.cache_hits.fetch_add(1, Ordering::Relaxed);
                bodies.push(Some(body));
            }
            None => {
                ctx.metrics.cache_misses.fetch_add(1, Ordering::Relaxed);
                bodies.push(None);
                miss_idx.push(i);
            }
        }
    }
    let hits = m - miss_idx.len();

    if !miss_idx.is_empty() {
        let (tx, rx) = mpsc::channel();
        enqueue(
            ctx,
            parsed.shard_hash(),
            Job::Batch(BatchJob {
                method: parsed.method.to_string(),
                datasets: miss_idx.iter().map(|&i| parsed.datasets[i].clone()).collect(),
                grid: parsed.grid,
                overrides: parsed.overrides.clone(),
                trace: trace::current(),
                enqueued_at: Instant::now(),
                reply: tx,
            }),
        )?;
        let results = rx
            .recv()
            .map_err(|_| ApiError::internal("engine host exited before replying"))?;
        for (&i, result) in miss_idx.iter().zip(results) {
            let outcome = result.map_err(ApiError::from_engine)?;
            let rendered = Arc::new(render_outcome(
                parsed.method,
                parsed.grid,
                &parsed.datasets[i],
                &outcome,
                parsed.include_arranged,
                parsed.include_report,
            ));
            bodies[i] = Some(ctx.cache.get_or_put(keys[i].clone(), rendered));
        }
    }

    // Splice the per-item bodies (known-valid compact JSON, and the cache
    // payloads themselves) into the envelope verbatim — no re-parse.
    let mut results = String::with_capacity(
        bodies.iter().map(|b| b.as_ref().map_or(0, |s| s.len() + 1)).sum::<usize>() + 2,
    );
    results.push('[');
    for (i, b) in bodies.iter().enumerate() {
        if i > 0 {
            results.push(',');
        }
        results.push_str(b.as_ref().expect("every batch slot is a hit or a completed miss"));
    }
    results.push(']');
    let body = format!(
        "{{\"count\":{m},\"method\":\"{}\",\"results\":{results}}}",
        parsed.method
    );
    Ok(Response::json(200, body)
        .with_header("X-Cache", format!("hits={hits} misses={}", miss_idx.len())))
}
