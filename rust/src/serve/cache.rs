//! Byte-budgeted LRU cache for finished sort responses.
//!
//! Sorts are pure functions of `(method, canonicalized overrides, data,
//! grid)` — the whole crate is built around that determinism (batch
//! results are bit-identical to sequential ones, pool size never changes
//! bits). That makes caching trivial to get *right*: a hit replays the
//! exact serialized response body of the first computation, byte for
//! byte, with zero extra Engine steps.
//!
//! Keys carry an FNV-1a hash of the dataset's f32 bit patterns rather than
//! the data itself, plus the canonical (sorted-key JSON) override string
//! the handler builds — so two requests that differ only in JSON key order
//! or whitespace share an entry.

use std::collections::{BTreeMap, HashMap};
use std::sync::{Arc, Mutex};

/// Identity of one sort computation.
#[derive(Clone, Debug, PartialEq, Eq, Hash)]
pub struct CacheKey {
    /// Canonical method name (registry-resolved, not the request alias).
    pub method: String,
    /// Canonical serialization of the effective overrides + backend.
    pub config: String,
    pub grid: (usize, usize),
    /// FNV-1a over the dataset rows' f32 bit patterns.
    pub data_hash: u64,
    pub n: usize,
    pub d: usize,
}

struct Entry {
    body: Arc<String>,
    tick: u64,
    cost: usize,
}

struct State {
    map: HashMap<CacheKey, Entry>,
    /// LRU order: tick → key (ticks are unique; smallest = oldest).
    lru: BTreeMap<u64, CacheKey>,
    tick: u64,
    bytes: usize,
}

/// Thread-safe LRU over serialized response bodies, bounded by an
/// approximate byte budget (entry cost = body + key strings + overhead).
pub struct ResultCache {
    state: Mutex<State>,
    capacity: usize,
}

/// Fixed per-entry overhead charged on top of the string payloads
/// (hash-map slot, LRU node, counters).
const ENTRY_OVERHEAD: usize = 128;

impl ResultCache {
    pub fn new(capacity_bytes: usize) -> Self {
        ResultCache {
            state: Mutex::new(State {
                map: HashMap::new(),
                lru: BTreeMap::new(),
                tick: 0,
                bytes: 0,
            }),
            capacity: capacity_bytes,
        }
    }

    fn cost(key: &CacheKey, body: &str) -> usize {
        body.len() + key.method.len() + key.config.len() + ENTRY_OVERHEAD
    }

    /// Look up a finished response; a hit refreshes the entry's LRU slot.
    pub fn get(&self, key: &CacheKey) -> Option<Arc<String>> {
        let mut guard = self.state.lock().expect("cache mutex poisoned");
        let st = &mut *guard;
        st.tick += 1;
        let fresh = st.tick;
        let entry = st.map.get_mut(key)?;
        let stale = std::mem::replace(&mut entry.tick, fresh);
        let body = entry.body.clone();
        st.lru.remove(&stale);
        st.lru.insert(fresh, key.clone());
        Some(body)
    }

    /// Insert (or refresh) a finished response, evicting least-recently
    /// used entries until the byte budget holds. Bodies larger than the
    /// whole budget are simply not cached.
    pub fn put(&self, key: CacheKey, body: Arc<String>) {
        let cost = Self::cost(&key, &body);
        if cost > self.capacity {
            return;
        }
        let mut guard = self.state.lock().expect("cache mutex poisoned");
        let st = &mut *guard;
        if let Some(old) = st.map.remove(&key) {
            st.lru.remove(&old.tick);
            st.bytes -= old.cost;
        }
        while st.bytes + cost > self.capacity {
            let Some((&oldest, _)) = st.lru.iter().next() else { break };
            let victim = st.lru.remove(&oldest).expect("lru key just observed");
            if let Some(e) = st.map.remove(&victim) {
                st.bytes -= e.cost;
            }
        }
        st.tick += 1;
        let tick = st.tick;
        st.lru.insert(tick, key.clone());
        st.map.insert(key, Entry { body, tick, cost });
        st.bytes += cost;
    }

    /// Atomic "insert unless present": returns the body every response
    /// for this key should use. First writer wins — when two identical
    /// requests miss concurrently and both compute (their bodies can
    /// differ in fields like `wall_secs`), all responses from the first
    /// insert onward serve the same bytes, preserving the byte-identical
    /// replay contract.
    pub fn get_or_put(&self, key: CacheKey, body: Arc<String>) -> Arc<String> {
        let cost = Self::cost(&key, &body);
        let mut guard = self.state.lock().expect("cache mutex poisoned");
        let st = &mut *guard;
        st.tick += 1;
        let fresh = st.tick;
        if let Some(entry) = st.map.get_mut(&key) {
            let stale = std::mem::replace(&mut entry.tick, fresh);
            let existing = entry.body.clone();
            st.lru.remove(&stale);
            st.lru.insert(fresh, key);
            return existing;
        }
        if cost > self.capacity {
            return body; // not cacheable; still serve the computed result
        }
        while st.bytes + cost > self.capacity {
            let Some((&oldest, _)) = st.lru.iter().next() else { break };
            let victim = st.lru.remove(&oldest).expect("lru key just observed");
            if let Some(e) = st.map.remove(&victim) {
                st.bytes -= e.cost;
            }
        }
        st.lru.insert(fresh, key.clone());
        st.map.insert(key, Entry { body: body.clone(), tick: fresh, cost });
        st.bytes += cost;
        body
    }

    /// (entries, approximate bytes) currently held.
    pub fn stats(&self) -> (usize, usize) {
        let st = self.state.lock().expect("cache mutex poisoned");
        (st.map.len(), st.bytes)
    }
}

/// FNV-1a 64-bit.
pub fn fnv1a(bytes: &[u8]) -> u64 {
    let mut h = 0xcbf29ce484222325u64;
    for &b in bytes {
        h ^= b as u64;
        h = h.wrapping_mul(0x100000001b3);
    }
    h
}

/// Hash a dataset's rows by exact f32 bit pattern (NaN-safe, -0.0 ≠ 0.0 —
/// bit-identity is the contract, not numeric equality).
pub fn hash_rows(rows: &[f32]) -> u64 {
    let mut h = 0xcbf29ce484222325u64;
    for v in rows {
        for b in v.to_bits().to_le_bytes() {
            h ^= b as u64;
            h = h.wrapping_mul(0x100000001b3);
        }
    }
    h
}

#[cfg(test)]
mod tests {
    use super::*;

    fn key(tag: &str) -> CacheKey {
        CacheKey {
            method: "softsort".into(),
            config: tag.into(),
            grid: (4, 4),
            data_hash: fnv1a(tag.as_bytes()),
            n: 16,
            d: 3,
        }
    }

    #[test]
    fn hit_returns_the_exact_stored_body() {
        let cache = ResultCache::new(64 * 1024);
        assert!(cache.get(&key("a")).is_none());
        cache.put(key("a"), Arc::new("{\"perm\":[1,0]}".to_string()));
        assert_eq!(cache.get(&key("a")).unwrap().as_str(), "{\"perm\":[1,0]}");
        assert_eq!(cache.stats().0, 1);
    }

    #[test]
    fn evicts_least_recently_used_under_byte_pressure() {
        // Budget fits exactly two entries.
        let body = "x".repeat(100);
        let one = ResultCache::cost(&key("a"), &body);
        let cache = ResultCache::new(2 * one);
        cache.put(key("a"), Arc::new(body.clone()));
        cache.put(key("b"), Arc::new(body.clone()));
        // Touch "a" so "b" is the LRU victim.
        assert!(cache.get(&key("a")).is_some());
        cache.put(key("c"), Arc::new(body.clone()));
        assert!(cache.get(&key("a")).is_some(), "recently used survives");
        assert!(cache.get(&key("b")).is_none(), "LRU evicted");
        assert!(cache.get(&key("c")).is_some());
        let (entries, bytes) = cache.stats();
        assert_eq!(entries, 2);
        assert!(bytes <= 2 * one);
    }

    #[test]
    fn oversized_bodies_are_not_cached_and_reinsert_replaces() {
        let cache = ResultCache::new(256);
        cache.put(key("huge"), Arc::new("y".repeat(10_000)));
        assert!(cache.get(&key("huge")).is_none());
        cache.put(key("a"), Arc::new("v1".to_string()));
        cache.put(key("a"), Arc::new("v2".to_string()));
        assert_eq!(cache.get(&key("a")).unwrap().as_str(), "v2");
        assert_eq!(cache.stats().0, 1);
    }

    #[test]
    fn get_or_put_is_first_writer_wins() {
        let cache = ResultCache::new(64 * 1024);
        let first = cache.get_or_put(key("a"), Arc::new("body-A".to_string()));
        assert_eq!(first.as_str(), "body-A");
        // A concurrent identical computation must converge on the stored
        // body, not overwrite it.
        let second = cache.get_or_put(key("a"), Arc::new("body-B".to_string()));
        assert_eq!(second.as_str(), "body-A");
        assert_eq!(cache.get(&key("a")).unwrap().as_str(), "body-A");
        assert_eq!(cache.stats().0, 1);
        // Uncacheably large bodies are still returned to the caller.
        let huge = cache.get_or_put(key("huge"), Arc::new("z".repeat(100_000)));
        assert_eq!(huge.len(), 100_000);
        assert!(cache.get(&key("huge")).is_none());
    }

    #[test]
    fn row_hash_is_bit_exact() {
        assert_eq!(hash_rows(&[1.0, 2.0]), hash_rows(&[1.0, 2.0]));
        assert_ne!(hash_rows(&[1.0, 2.0]), hash_rows(&[2.0, 1.0]));
        assert_ne!(hash_rows(&[0.0]), hash_rows(&[-0.0]));
    }
}
