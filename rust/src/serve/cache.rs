//! Byte-budgeted LRU cache for finished sort responses.
//!
//! Sorts are pure functions of `(method, canonicalized overrides, data,
//! grid)` — the whole crate is built around that determinism (batch
//! results are bit-identical to sequential ones, pool size never changes
//! bits). That makes caching trivial to get *right*: a hit replays the
//! exact serialized response body of the first computation, byte for
//! byte, with zero extra Engine steps.
//!
//! Keys carry an FNV-1a hash of the dataset's f32 bit patterns rather than
//! the data itself, plus the canonical (sorted-key JSON) override string
//! the handler builds — so two requests that differ only in JSON key order
//! or whitespace share an entry.
//!
//! Two robustness properties layered on top:
//!
//! - **Poison recovery**: nothing inside the state lock is supposed to
//!   panic, but if a writer ever does, the next locker recovers the mutex
//!   (`into_inner` + `clear_poison`) and resets to a *cold* cache rather
//!   than crashing every subsequent request. A lost cache costs recompute;
//!   a poisoned `expect` costs the whole serve plane.
//! - **Persistence** (optional): with a [`Store`] attached, every insert
//!   is appended to the spill file, so the cache survives restarts. The
//!   cache tracks the on-disk byte size of its *live* entries
//!   (`spill_live`) and asks the store to compact once dead bytes (from
//!   overwrites and evictions) exceed the store's budget.

use std::collections::{BTreeMap, HashMap};
use std::sync::{Arc, Mutex, MutexGuard};

use super::store::Store;

/// Identity of one sort computation.
#[derive(Clone, Debug, PartialEq, Eq, Hash)]
pub struct CacheKey {
    /// Canonical method name (registry-resolved, not the request alias).
    pub method: String,
    /// Canonical serialization of the effective overrides + backend.
    pub config: String,
    pub grid: (usize, usize),
    /// FNV-1a over the dataset rows' f32 bit patterns.
    pub data_hash: u64,
    pub n: usize,
    pub d: usize,
}

struct Entry {
    body: Arc<String>,
    tick: u64,
    cost: usize,
    /// On-disk record size for this entry (tracked even without a store,
    /// so attaching one after replay starts with correct accounting).
    spill: u64,
}

struct State {
    map: HashMap<CacheKey, Entry>,
    /// LRU order: tick → key (ticks are unique; smallest = oldest).
    lru: BTreeMap<u64, CacheKey>,
    tick: u64,
    bytes: usize,
    /// Sum of `Entry::spill` over live entries.
    spill_live: u64,
}

impl State {
    fn cold() -> State {
        State {
            map: HashMap::new(),
            lru: BTreeMap::new(),
            tick: 0,
            bytes: 0,
            spill_live: 0,
        }
    }
}

/// Thread-safe LRU over serialized response bodies, bounded by an
/// approximate byte budget (entry cost = body + key strings + overhead).
pub struct ResultCache {
    state: Mutex<State>,
    capacity: usize,
    store: Option<Arc<Store>>,
}

/// Fixed per-entry overhead charged on top of the string payloads
/// (hash-map slot, LRU node, counters).
const ENTRY_OVERHEAD: usize = 128;

impl ResultCache {
    pub fn new(capacity_bytes: usize) -> Self {
        ResultCache {
            state: Mutex::new(State::cold()),
            capacity: capacity_bytes,
            store: None,
        }
    }

    /// Attach the persistence layer. Call *after* replaying the store's
    /// boot records into the cache (replaying through an attached store
    /// would re-append every record it just read).
    pub fn attach_store(&mut self, store: Arc<Store>) {
        self.store = Some(store);
    }

    /// Lock the state, recovering from a poisoned mutex by degrading to a
    /// cold cache: correctness never depended on the contents (misses just
    /// recompute), so dropping a possibly half-updated state is strictly
    /// safer than trusting it — and strictly better than panicking on
    /// every request forever.
    fn lock_state(&self) -> MutexGuard<'_, State> {
        match self.state.lock() {
            Ok(guard) => guard,
            Err(poisoned) => {
                let mut guard = poisoned.into_inner();
                *guard = State::cold();
                self.state.clear_poison();
                guard
            }
        }
    }

    fn cost(key: &CacheKey, body: &str) -> usize {
        body.len() + key.method.len() + key.config.len() + ENTRY_OVERHEAD
    }

    /// Look up a finished response; a hit refreshes the entry's LRU slot.
    pub fn get(&self, key: &CacheKey) -> Option<Arc<String>> {
        let mut guard = self.lock_state();
        let st = &mut *guard;
        st.tick += 1;
        let fresh = st.tick;
        let entry = st.map.get_mut(key)?;
        let stale = std::mem::replace(&mut entry.tick, fresh);
        let body = entry.body.clone();
        st.lru.remove(&stale);
        st.lru.insert(fresh, key.clone());
        Some(body)
    }

    /// Drop `key`'s current entry (if any) from the live maps.
    fn remove_entry(st: &mut State, key: &CacheKey) {
        if let Some(old) = st.map.remove(key) {
            st.lru.remove(&old.tick);
            st.bytes -= old.cost;
            st.spill_live -= old.spill;
        }
    }

    /// Evict least-recently-used entries until `cost` more bytes fit.
    fn evict_for(&self, st: &mut State, cost: usize) {
        while st.bytes + cost > self.capacity {
            let Some((&oldest, _)) = st.lru.iter().next() else { break };
            let victim = st.lru.remove(&oldest).expect("lru key just observed");
            if let Some(e) = st.map.remove(&victim) {
                st.bytes -= e.cost;
                st.spill_live -= e.spill;
            }
        }
    }

    /// Record a fresh insert on disk, compacting the spill file when the
    /// dead bytes left behind by overwrites/evictions exceed its budget.
    fn persist(&self, st: &mut State, key: &CacheKey, body: &str) {
        let Some(store) = &self.store else { return };
        store.append(key, body);
        if store.needs_compaction(st.spill_live) {
            // Oldest-first, so a future replay reconstructs LRU recency.
            let live: Vec<(CacheKey, Arc<String>)> = st
                .lru
                .values()
                .filter_map(|k| st.map.get(k).map(|e| (k.clone(), e.body.clone())))
                .collect();
            store.compact(&live);
        }
    }

    /// Insert (or refresh) a finished response, evicting least-recently
    /// used entries until the byte budget holds. Bodies larger than the
    /// whole budget are simply not cached.
    pub fn put(&self, key: CacheKey, body: Arc<String>) {
        let cost = Self::cost(&key, &body);
        if cost > self.capacity {
            return;
        }
        let spill = super::store::record_len(&key, &body);
        let mut guard = self.lock_state();
        let st = &mut *guard;
        Self::remove_entry(st, &key);
        self.evict_for(st, cost);
        st.tick += 1;
        let tick = st.tick;
        st.lru.insert(tick, key.clone());
        st.spill_live += spill;
        st.bytes += cost;
        st.map.insert(key.clone(), Entry { body: body.clone(), tick, cost, spill });
        // Persist after the live maps are updated: a compaction triggered
        // by this insert must see the entry it just appended.
        self.persist(st, &key, &body);
    }

    /// Atomic "insert unless present": returns the body every response
    /// for this key should use. First writer wins — when two identical
    /// requests miss concurrently and both compute (their bodies can
    /// differ in fields like `wall_secs`), all responses from the first
    /// insert onward serve the same bytes, preserving the byte-identical
    /// replay contract.
    pub fn get_or_put(&self, key: CacheKey, body: Arc<String>) -> Arc<String> {
        let cost = Self::cost(&key, &body);
        let mut guard = self.lock_state();
        let st = &mut *guard;
        st.tick += 1;
        let fresh = st.tick;
        if let Some(entry) = st.map.get_mut(&key) {
            let stale = std::mem::replace(&mut entry.tick, fresh);
            let existing = entry.body.clone();
            st.lru.remove(&stale);
            st.lru.insert(fresh, key);
            return existing;
        }
        if cost > self.capacity {
            return body; // not cacheable; still serve the computed result
        }
        self.evict_for(st, cost);
        let spill = super::store::record_len(&key, &body);
        st.lru.insert(fresh, key.clone());
        st.spill_live += spill;
        st.bytes += cost;
        st.map.insert(key.clone(), Entry { body: body.clone(), tick: fresh, cost, spill });
        self.persist(st, &key, &body);
        body
    }

    /// (entries, approximate bytes) currently held.
    pub fn stats(&self) -> (usize, usize) {
        let st = self.lock_state();
        (st.map.len(), st.bytes)
    }
}

/// FNV-1a 64-bit.
pub fn fnv1a(bytes: &[u8]) -> u64 {
    let mut h = 0xcbf29ce484222325u64;
    for &b in bytes {
        h ^= b as u64;
        h = h.wrapping_mul(0x100000001b3);
    }
    h
}

/// Hash a dataset's rows by exact f32 bit pattern (NaN-safe, -0.0 ≠ 0.0 —
/// bit-identity is the contract, not numeric equality).
pub fn hash_rows(rows: &[f32]) -> u64 {
    let mut h = 0xcbf29ce484222325u64;
    for v in rows {
        for b in v.to_bits().to_le_bytes() {
            h ^= b as u64;
            h = h.wrapping_mul(0x100000001b3);
        }
    }
    h
}

#[cfg(test)]
mod tests {
    use super::*;

    fn key(tag: &str) -> CacheKey {
        CacheKey {
            method: "softsort".into(),
            config: tag.into(),
            grid: (4, 4),
            data_hash: fnv1a(tag.as_bytes()),
            n: 16,
            d: 3,
        }
    }

    #[test]
    fn hit_returns_the_exact_stored_body() {
        let cache = ResultCache::new(64 * 1024);
        assert!(cache.get(&key("a")).is_none());
        cache.put(key("a"), Arc::new("{\"perm\":[1,0]}".to_string()));
        assert_eq!(cache.get(&key("a")).unwrap().as_str(), "{\"perm\":[1,0]}");
        assert_eq!(cache.stats().0, 1);
    }

    #[test]
    fn evicts_least_recently_used_under_byte_pressure() {
        // Budget fits exactly two entries.
        let body = "x".repeat(100);
        let one = ResultCache::cost(&key("a"), &body);
        let cache = ResultCache::new(2 * one);
        cache.put(key("a"), Arc::new(body.clone()));
        cache.put(key("b"), Arc::new(body.clone()));
        // Touch "a" so "b" is the LRU victim.
        assert!(cache.get(&key("a")).is_some());
        cache.put(key("c"), Arc::new(body.clone()));
        assert!(cache.get(&key("a")).is_some(), "recently used survives");
        assert!(cache.get(&key("b")).is_none(), "LRU evicted");
        assert!(cache.get(&key("c")).is_some());
        let (entries, bytes) = cache.stats();
        assert_eq!(entries, 2);
        assert!(bytes <= 2 * one);
    }

    #[test]
    fn oversized_bodies_are_not_cached_and_reinsert_replaces() {
        let cache = ResultCache::new(256);
        cache.put(key("huge"), Arc::new("y".repeat(10_000)));
        assert!(cache.get(&key("huge")).is_none());
        cache.put(key("a"), Arc::new("v1".to_string()));
        cache.put(key("a"), Arc::new("v2".to_string()));
        assert_eq!(cache.get(&key("a")).unwrap().as_str(), "v2");
        assert_eq!(cache.stats().0, 1);
    }

    #[test]
    fn get_or_put_is_first_writer_wins() {
        let cache = ResultCache::new(64 * 1024);
        let first = cache.get_or_put(key("a"), Arc::new("body-A".to_string()));
        assert_eq!(first.as_str(), "body-A");
        // A concurrent identical computation must converge on the stored
        // body, not overwrite it.
        let second = cache.get_or_put(key("a"), Arc::new("body-B".to_string()));
        assert_eq!(second.as_str(), "body-A");
        assert_eq!(cache.get(&key("a")).unwrap().as_str(), "body-A");
        assert_eq!(cache.stats().0, 1);
        // Uncacheably large bodies are still returned to the caller.
        let huge = cache.get_or_put(key("huge"), Arc::new("z".repeat(100_000)));
        assert_eq!(huge.len(), 100_000);
        assert!(cache.get(&key("huge")).is_none());
    }

    #[test]
    fn poisoned_mutex_degrades_to_a_cold_cache_instead_of_panicking() {
        let cache = Arc::new(ResultCache::new(64 * 1024));
        cache.put(key("warm"), Arc::new("before".to_string()));
        // Poison the lock the way a buggy writer would: panic while held.
        let c2 = cache.clone();
        let poisoner = std::thread::spawn(move || {
            let _guard = c2.state.lock().unwrap();
            panic!("deliberate poison for test");
        });
        assert!(poisoner.join().is_err());
        // Every operation keeps working; the cache simply went cold.
        assert!(cache.get(&key("warm")).is_none(), "cold after recovery");
        cache.put(key("again"), Arc::new("after".to_string()));
        assert_eq!(cache.get(&key("again")).unwrap().as_str(), "after");
        assert_eq!(cache.stats().0, 1);
    }

    #[test]
    fn attached_store_persists_inserts_and_compacts_dead_bytes() {
        let path = std::env::temp_dir().join(format!(
            "sssort-cache-persist-{}.spill",
            std::process::id()
        ));
        let _ = std::fs::remove_file(&path);
        {
            let (store, replayed) = Store::open(&path).unwrap();
            assert!(replayed.is_empty());
            let store = Arc::new(store);
            let mut cache = ResultCache::new(64 * 1024);
            cache.attach_store(store.clone());
            // Overwrite one key enough times that dead bytes blow the
            // 64 KiB compaction slack; live stays at a single entry.
            for i in 0..80 {
                cache.put(key("hot"), Arc::new(format!("{:<2048}", i)));
            }
            cache.put(key("side"), Arc::new("kept".to_string()));
            let v = store.view();
            assert!(v.compactions >= 1, "overwrites trigger compaction");
            // ~170 KiB of appends without compaction; well under 64 KiB with.
            assert!(v.file_bytes < 64 * 1024, "dead bytes reclaimed");
        }
        // Boot replay: the file still holds some dead overwrites appended
        // since the last compaction; replaying through a cache (last write
        // wins) reconstructs exactly the live state.
        let (store, replayed) = Store::open(&path).unwrap();
        assert!(store.view().replayed >= 2);
        let boot = ResultCache::new(64 * 1024);
        for (k, b) in replayed {
            boot.put(k, Arc::new(b));
        }
        assert_eq!(boot.stats().0, 2, "live entries survive restart");
        assert!(boot.get(&key("hot")).unwrap().starts_with("79"));
        assert_eq!(boot.get(&key("side")).unwrap().as_str(), "kept");
        let _ = std::fs::remove_file(&path);
    }
}
