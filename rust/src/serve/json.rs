//! JSON construction and serialization ergonomics for the serve layer —
//! and for every other emitter of machine-readable output in the crate.
//!
//! The crate already owns a full JSON value type and parser
//! ([`crate::util::json::Json`], in the spirit of the `smoljson`
//! exemplar); what was missing was the *writing* side: before this module,
//! `bench::write_json_report` (used by the `runtime_micro`/`scaling`
//! benches) hand-assembled JSON with `format!` and ad-hoc escaping. This
//! module is the one way to build and serialize JSON documents:
//!
//! * [`obj`]/[`arr`]/[`num`] builders plus `From` impls for the common
//!   scalar types, so handler code reads as data, not string plumbing;
//! * a stable, parser-round-tripping compact form (via
//!   [`Json::to_string_compact`]) for HTTP bodies and cache entries — the
//!   `Obj` variant is a `BTreeMap`, so serialization order is canonical,
//!   which is what lets the result cache compare and replay bodies
//!   byte-for-byte;
//! * [`to_string_pretty`] for human-facing documents (bench reports, the
//!   `/metrics` JSON view).

pub use crate::util::json::{Json, JsonError};

use std::collections::BTreeMap;

/// Build a JSON object from key/value pairs. Keys are deduplicated
/// last-wins and serialized in sorted order (the `Obj` variant is a
/// `BTreeMap`), so two objects with the same contents always serialize to
/// the same bytes.
pub fn obj<K, I>(pairs: I) -> Json
where
    K: Into<String>,
    I: IntoIterator<Item = (K, Json)>,
{
    Json::Obj(pairs.into_iter().map(|(k, v)| (k.into(), v)).collect::<BTreeMap<_, _>>())
}

/// Build a JSON array from any iterator of values.
pub fn arr<I: IntoIterator<Item = Json>>(items: I) -> Json {
    Json::Arr(items.into_iter().collect())
}

/// A number that is always valid JSON: non-finite values (which raw JSON
/// cannot express) map to `null`.
pub fn num(v: f64) -> Json {
    if v.is_finite() {
        Json::Num(v)
    } else {
        Json::Null
    }
}

impl From<&str> for Json {
    fn from(v: &str) -> Json {
        Json::Str(v.to_string())
    }
}

impl From<String> for Json {
    fn from(v: String) -> Json {
        Json::Str(v)
    }
}

impl From<bool> for Json {
    fn from(v: bool) -> Json {
        Json::Bool(v)
    }
}

impl From<f64> for Json {
    fn from(v: f64) -> Json {
        num(v)
    }
}

impl From<f32> for Json {
    fn from(v: f32) -> Json {
        num(v as f64)
    }
}

impl From<usize> for Json {
    fn from(v: usize) -> Json {
        Json::Num(v as f64)
    }
}

impl From<u64> for Json {
    fn from(v: u64) -> Json {
        Json::Num(v as f64)
    }
}

impl From<u32> for Json {
    fn from(v: u32) -> Json {
        Json::Num(v as f64)
    }
}

impl From<u16> for Json {
    fn from(v: u16) -> Json {
        Json::Num(v as f64)
    }
}

impl From<i64> for Json {
    fn from(v: i64) -> Json {
        Json::Num(v as f64)
    }
}

impl From<i32> for Json {
    fn from(v: i32) -> Json {
        Json::Num(v as f64)
    }
}

impl From<Vec<Json>> for Json {
    fn from(v: Vec<Json>) -> Json {
        Json::Arr(v)
    }
}

/// Two-space-indented serialization (round-trips through [`Json::parse`]
/// exactly like the compact form; scalars and empty containers are
/// delegated to it).
pub fn to_string_pretty(j: &Json) -> String {
    let mut out = String::new();
    write_pretty(j, 0, &mut out);
    out
}

fn write_pretty(j: &Json, depth: usize, out: &mut String) {
    const INDENT: &str = "  ";
    match j {
        Json::Arr(a) if !a.is_empty() => {
            out.push('[');
            for (i, v) in a.iter().enumerate() {
                if i > 0 {
                    out.push(',');
                }
                out.push('\n');
                out.push_str(&INDENT.repeat(depth + 1));
                write_pretty(v, depth + 1, out);
            }
            out.push('\n');
            out.push_str(&INDENT.repeat(depth));
            out.push(']');
        }
        Json::Obj(m) if !m.is_empty() => {
            out.push('{');
            for (i, (k, v)) in m.iter().enumerate() {
                if i > 0 {
                    out.push(',');
                }
                out.push('\n');
                out.push_str(&INDENT.repeat(depth + 1));
                out.push_str(&Json::Str(k.clone()).to_string_compact());
                out.push_str(": ");
                write_pretty(v, depth + 1, out);
            }
            out.push('\n');
            out.push_str(&INDENT.repeat(depth));
            out.push('}');
        }
        scalar => out.push_str(&scalar.to_string_compact()),
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn builders_compose_and_serialize_canonically() {
        let doc = obj([
            ("name", Json::from("sort")),
            ("count", Json::from(3usize)),
            ("ok", Json::from(true)),
            ("items", arr((0..3).map(Json::from))),
            ("nan", num(f64::NAN)),
        ]);
        let s = doc.to_string_compact();
        // BTreeMap ⇒ sorted keys ⇒ byte-stable output.
        assert_eq!(
            s,
            r#"{"count":3,"items":[0,1,2],"name":"sort","nan":null,"ok":true}"#
        );
        assert_eq!(Json::parse(&s).unwrap(), doc);
    }

    #[test]
    fn duplicate_keys_are_last_wins() {
        let doc = obj([("k", Json::from(1i64)), ("k", Json::from(2i64))]);
        assert_eq!(doc.to_string_compact(), r#"{"k":2}"#);
    }

    #[test]
    fn pretty_round_trips_through_the_parser() {
        let doc = obj([
            ("a", arr([Json::from(1i64), obj([("b", Json::Null)])])),
            ("empty_arr", arr([])),
            ("empty_obj", obj::<String, _>([])),
            ("s", Json::from("line\nbreak")),
        ]);
        let pretty = to_string_pretty(&doc);
        assert!(pretty.contains('\n'));
        assert_eq!(Json::parse(&pretty).unwrap(), doc);
        assert_eq!(to_string_pretty(&Json::from(7i64)), "7");
    }
}
