//! Live service metrics: lock-free atomic counters plus per-method latency
//! histograms, exported two ways from `GET /metrics` — a JSON document for
//! humans/tests and the Prometheus text exposition format for scrapers.
//!
//! Counter updates sit on the request hot path, so they are plain relaxed
//! atomics; the only lock is the method-name → histogram map, taken just
//! long enough to clone an `Arc` (bucket increments happen outside it).
//!
//! Gauge-style state (cache size, per-shard queue depth/warmth, spill-file
//! counters) lives in the subsystems that own it; the handler snapshots it
//! into a [`ServeView`] per scrape and passes that in, keeping `Metrics`
//! free of references into the rest of the server.

use std::collections::{BTreeMap, VecDeque};
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, Mutex};
use std::time::Instant;

use super::json::{arr, num, obj, Json};
use super::store::PersistView;
use crate::trace;

/// Histogram bucket upper bounds, in seconds (plus an implicit +Inf).
pub const BUCKET_BOUNDS: [f64; 12] =
    [0.001, 0.0025, 0.005, 0.01, 0.025, 0.05, 0.1, 0.25, 0.5, 1.0, 2.5, 5.0];

/// Samples kept by each histogram's sliding-window quantile sketch. The
/// memory is fixed (`WINDOW_CAP` f64s per histogram); quantiles are exact
/// over the last `WINDOW_CAP` observations rather than bucket-rounded
/// over all of them.
pub const WINDOW_CAP: usize = 512;

/// Fixed-memory ring of the most recent observations (the quantile
/// sketch behind p50/p95/p99).
#[derive(Default)]
struct Window {
    buf: Vec<f64>,
    next: usize,
}

impl Window {
    fn push(&mut self, v: f64) {
        if self.buf.len() < WINDOW_CAP {
            self.buf.push(v);
        } else {
            self.buf[self.next] = v;
        }
        self.next = (self.next + 1) % WINDOW_CAP;
    }

    /// Exact quantiles over the window: `(p50, p95, p99)` in the sample
    /// unit, `None` while empty.
    fn quantiles(&self) -> Option<(f64, f64, f64)> {
        if self.buf.is_empty() {
            return None;
        }
        let mut sorted = self.buf.clone();
        sorted.sort_by(|a, b| a.partial_cmp(b).unwrap_or(std::cmp::Ordering::Equal));
        let at = |q: f64| {
            let idx = (q * (sorted.len() - 1) as f64).round() as usize;
            sorted[idx.min(sorted.len() - 1)]
        };
        Some((at(0.5), at(0.95), at(0.99)))
    }
}

/// One latency histogram: fixed log-spaced buckets + overflow (the
/// cumulative Prometheus exposition), plus a sliding [`Window`] for exact
/// recent p50/p95/p99.
#[derive(Default)]
pub struct Histogram {
    buckets: [AtomicU64; BUCKET_BOUNDS.len() + 1],
    sum_micros: AtomicU64,
    count: AtomicU64,
    window: Mutex<Window>,
}

/// Index of the +Inf overflow bucket.
const OVERFLOW_IDX: usize = BUCKET_BOUNDS.len();

impl Histogram {
    pub fn observe(&self, secs: f64) {
        let idx = BUCKET_BOUNDS.iter().position(|&b| secs <= b).unwrap_or(OVERFLOW_IDX);
        self.buckets[idx].fetch_add(1, Ordering::Relaxed);
        self.sum_micros.fetch_add((secs * 1e6).max(0.0) as u64, Ordering::Relaxed);
        self.count.fetch_add(1, Ordering::Relaxed);
        let mut w = self.window.lock().unwrap_or_else(|e| e.into_inner());
        w.push(secs);
    }

    fn snapshot(&self) -> (Vec<u64>, f64, u64) {
        let buckets: Vec<u64> =
            self.buckets.iter().map(|b| b.load(Ordering::Relaxed)).collect();
        let sum = self.sum_micros.load(Ordering::Relaxed) as f64 / 1e6;
        let count = self.count.load(Ordering::Relaxed);
        (buckets, sum, count)
    }

    /// Sliding-window `(p50, p95, p99)` in seconds (`None` while empty).
    pub fn window_quantiles(&self) -> Option<(f64, f64, f64)> {
        self.window.lock().unwrap_or_else(|e| e.into_inner()).quantiles()
    }

    /// Upper bound of the bucket where the `q`-quantile falls (`None` when
    /// it lands in the overflow bucket or the histogram is empty).
    fn quantile_bound(buckets: &[u64], count: u64, q: f64) -> Option<f64> {
        if count == 0 {
            return None;
        }
        let target = (q * count as f64).ceil().max(1.0) as u64;
        let mut cum = 0u64;
        for (i, &b) in buckets.iter().enumerate() {
            cum += b;
            if cum >= target {
                return BUCKET_BOUNDS.get(i).copied();
            }
        }
        None
    }
}

/// Sort runs kept per method in the convergence sliding window.
pub const CONV_WINDOW: usize = 256;

/// Sliding-window convergence aggregates for one method: sort *quality*
/// telemetry, so a regression in loss or rejected-phase rate is as
/// visible on `/metrics` as a latency regression.
#[derive(Default)]
struct ConvWindow {
    /// Total runs folded in (beyond the window).
    runs: u64,
    loss: VecDeque<f64>,
    rejected_rate: VecDeque<f64>,
    /// Only runs that computed a DPQ land here (heuristics and
    /// small-N paths may not).
    dpq: VecDeque<f64>,
}

impl ConvWindow {
    fn push(dq: &mut VecDeque<f64>, v: f64) {
        if !v.is_finite() {
            return;
        }
        if dq.len() == CONV_WINDOW {
            dq.pop_front();
        }
        dq.push_back(v);
    }

    fn mean(dq: &VecDeque<f64>) -> Option<f64> {
        (!dq.is_empty()).then(|| dq.iter().sum::<f64>() / dq.len() as f64)
    }
}

/// Point-in-time state of one shard, snapshotted per `/metrics` scrape.
#[derive(Clone, Copy, Debug)]
pub struct ShardView {
    pub id: usize,
    pub alive: bool,
    pub queue_depth: usize,
    pub jobs: u64,
    /// `(n, d, h)` step sessions memoized on the shard's engine — the
    /// warmth the affinity hash exists to preserve.
    pub memo_entries: u64,
}

/// Everything gauge-like the handler snapshots for one scrape.
#[derive(Default)]
pub struct ServeView {
    pub cache_entries: usize,
    pub cache_bytes: usize,
    /// Jobs queued (not yet popped) across every shard.
    pub queue_depth: usize,
    pub shards: Vec<ShardView>,
    /// `None` when the server runs without `--cache-file`.
    pub persist: Option<PersistView>,
    /// Finished-trace LRU capacity in effect (`--trace-keep`).
    pub trace_keep: u64,
    /// Finished traces evicted from the LRU since process start.
    pub trace_evictions: u64,
}

/// All live counters for one server instance.
pub struct Metrics {
    pub requests: AtomicU64,
    pub responses_2xx: AtomicU64,
    pub responses_4xx: AtomicU64,
    pub responses_5xx: AtomicU64,
    pub cache_hits: AtomicU64,
    pub cache_misses: AtomicU64,
    /// Jobs actually executed by the engine hosts (cache hits never reach
    /// them — the "zero extra Engine steps on a repeat request" check).
    pub engine_jobs: AtomicU64,
    /// Sum over engine-executed sorts of their per-phase tile count
    /// (`RunReport::tiles`: B for a tiled ShuffleSoftSort run, 1 for the
    /// full executor, 0 for methods without a phase executor) — the
    /// observable that tiled requests really ran tiled.
    pub phase_tiles: AtomicU64,
    pub queue_rejections: AtomicU64,
    /// Jobs that landed on a non-home shard (home saturated or dead).
    pub shard_steals: AtomicU64,
    /// Requests refused with 429 by the token-bucket limiter.
    pub rate_limited: AtomicU64,
    /// Requests refused with 401 (missing or wrong bearer token).
    pub auth_failures: AtomicU64,
    /// Speculatively-traced requests kept by the tail sampler (root span
    /// ran past `--trace-tail-ms` after the head sampler skipped them).
    pub trace_tail_kept: AtomicU64,
    /// Time jobs spent in a shard sub-queue before an engine host popped
    /// them. Observed for every engine job, traced or not.
    pub queue_wait: Histogram,
    /// Driver-phase wall time, fed from finished traces' sampled `phase`
    /// spans ([`Metrics::observe_trace`]).
    pub phase_exec: Histogram,
    /// Executor-tile wall time, fed from finished traces' `tile` spans.
    pub tile_exec: Histogram,
    /// Cumulative per-step-family kernel time (µs) and step counts,
    /// index-aligned with [`trace::FAMILY_NAMES`].
    step_family_micros: [AtomicU64; trace::FAMILY_NAMES.len()],
    step_family_steps: [AtomicU64; trace::FAMILY_NAMES.len()],
    latency: Mutex<BTreeMap<String, Arc<Histogram>>>,
    /// Per-method sliding-window convergence aggregates, fed by the engine
    /// hosts after every completed sort ([`Metrics::observe_convergence`]).
    convergence: Mutex<BTreeMap<String, ConvWindow>>,
    started: Instant,
}

impl Default for Metrics {
    fn default() -> Self {
        Metrics::new()
    }
}

impl Metrics {
    pub fn new() -> Self {
        Metrics {
            requests: AtomicU64::new(0),
            responses_2xx: AtomicU64::new(0),
            responses_4xx: AtomicU64::new(0),
            responses_5xx: AtomicU64::new(0),
            cache_hits: AtomicU64::new(0),
            cache_misses: AtomicU64::new(0),
            engine_jobs: AtomicU64::new(0),
            phase_tiles: AtomicU64::new(0),
            queue_rejections: AtomicU64::new(0),
            shard_steals: AtomicU64::new(0),
            rate_limited: AtomicU64::new(0),
            auth_failures: AtomicU64::new(0),
            trace_tail_kept: AtomicU64::new(0),
            queue_wait: Histogram::default(),
            phase_exec: Histogram::default(),
            tile_exec: Histogram::default(),
            step_family_micros: Default::default(),
            step_family_steps: Default::default(),
            latency: Mutex::new(BTreeMap::new()),
            convergence: Mutex::new(BTreeMap::new()),
            started: Instant::now(),
        }
    }

    /// Seconds since this server's metrics were created (≈ boot).
    pub fn uptime_seconds(&self) -> f64 {
        self.started.elapsed().as_secs_f64()
    }

    /// Fold one completed sort's quality telemetry into the per-method
    /// convergence window. `dpq` may be non-finite (not computed for this
    /// run) — it is skipped while loss/rejected-rate still count.
    pub fn observe_convergence(&self, method: &str, loss: f64, rejected_rate: f64, dpq: f64) {
        let mut map = self.lock_convergence();
        let w = map.entry(method.to_string()).or_default();
        w.runs += 1;
        ConvWindow::push(&mut w.loss, loss);
        ConvWindow::push(&mut w.rejected_rate, rejected_rate);
        ConvWindow::push(&mut w.dpq, dpq);
    }

    fn lock_convergence(&self) -> std::sync::MutexGuard<'_, BTreeMap<String, ConvWindow>> {
        self.convergence.lock().unwrap_or_else(|poisoned| {
            self.convergence.clear_poison();
            poisoned.into_inner()
        })
    }

    /// Fold a finished trace into the convergence-telemetry aggregates:
    /// sampled `phase` spans and `tile` spans feed their histograms,
    /// step-family spans feed the per-family time/step totals. Queue wait
    /// is deliberately NOT re-observed here — the engine host already
    /// observed it for every job, traced or not.
    pub fn observe_trace(&self, t: &trace::FinishedTrace) {
        for s in &t.spans {
            let secs = s.dur_us as f64 / 1e6;
            match s.name {
                "phase" => self.phase_exec.observe(secs),
                "tile" => self.tile_exec.observe(secs),
                name => {
                    let Some(i) = trace::FAMILY_NAMES.iter().position(|f| *f == name)
                    else {
                        continue;
                    };
                    self.step_family_micros[i].fetch_add(s.dur_us, Ordering::Relaxed);
                    let steps = s
                        .attrs
                        .iter()
                        .flatten()
                        .find_map(|(k, v)| match v {
                            trace::AttrValue::U64(c) if *k == "steps" => Some(*c),
                            _ => None,
                        })
                        .unwrap_or(1);
                    self.step_family_steps[i].fetch_add(steps, Ordering::Relaxed);
                }
            }
        }
    }

    /// Count a response by status class.
    pub fn status(&self, code: u16) {
        let counter = match code {
            200..=299 => &self.responses_2xx,
            400..=499 => &self.responses_4xx,
            _ => &self.responses_5xx,
        };
        counter.fetch_add(1, Ordering::Relaxed);
    }

    /// Record one engine-executed sort's wall time under its method name.
    pub fn observe(&self, method: &str, secs: f64) {
        let hist = {
            let mut map = self.lock_latency();
            map.entry(method.to_string()).or_default().clone()
        };
        hist.observe(secs);
    }

    /// Latency-map lock with poison recovery: the map's invariants are a
    /// `BTreeMap` of `Arc`s, valid whatever a panicking holder was doing.
    fn lock_latency(&self) -> std::sync::MutexGuard<'_, BTreeMap<String, Arc<Histogram>>> {
        self.latency.lock().unwrap_or_else(|poisoned| {
            self.latency.clear_poison();
            poisoned.into_inner()
        })
    }

    fn load(counter: &AtomicU64) -> u64 {
        counter.load(Ordering::Relaxed)
    }

    fn shard_json(s: &ShardView) -> Json {
        obj([
            ("id", Json::from(s.id)),
            ("alive", Json::from(s.alive)),
            ("queue_depth", Json::from(s.queue_depth)),
            ("jobs", Json::from(s.jobs)),
            ("session_memo_entries", Json::from(s.memo_entries)),
        ])
    }

    fn persist_json(p: &PersistView) -> Json {
        obj([
            ("appends", Json::from(p.appends)),
            ("replayed", Json::from(p.replayed)),
            ("compactions", Json::from(p.compactions)),
            ("corrupt_dropped", Json::from(p.corrupt_dropped)),
            ("errors", Json::from(p.errors)),
            ("file_bytes", Json::from(p.file_bytes)),
        ])
    }

    /// Summary object for one histogram (shared by the per-method latency
    /// map and the span-derived histograms).
    fn hist_json(h: &Histogram) -> Json {
        let (buckets, sum, count) = h.snapshot();
        let mean = if count > 0 { sum / count as f64 } else { 0.0 };
        let quant = |q| {
            Histogram::quantile_bound(&buckets, count, q)
                .map(|b| num(b * 1e3))
                .unwrap_or(Json::Null)
        };
        // Bucket-bound quantiles cover the full history; the window
        // triple is exact over the last `WINDOW_CAP` observations.
        let (p50, p95, p99) = match h.window_quantiles() {
            Some((a, b, c)) => (num(a * 1e3), num(b * 1e3), num(c * 1e3)),
            None => (Json::Null, Json::Null, Json::Null),
        };
        obj([
            ("count", Json::from(count)),
            ("mean_ms", num(mean * 1e3)),
            ("p50_le_ms", quant(0.5)),
            ("p99_le_ms", quant(0.99)),
            ("p50_ms", p50),
            ("p95_ms", p95),
            ("p99_ms", p99),
            ("buckets", arr(buckets.into_iter().map(Json::from))),
        ])
    }

    /// JSON object of the per-method convergence windows.
    fn convergence_json(&self) -> Json {
        let map = self.lock_convergence();
        let items: Vec<(String, Json)> = map
            .iter()
            .map(|(name, w)| {
                let field = |dq| ConvWindow::mean(dq).map(num).unwrap_or(Json::Null);
                (
                    name.clone(),
                    obj([
                        ("runs", Json::from(w.runs)),
                        ("window", Json::from(w.loss.len())),
                        ("mean_loss", field(&w.loss)),
                        ("rejected_phase_rate", field(&w.rejected_rate)),
                        ("mean_dpq", field(&w.dpq)),
                    ]),
                )
            })
            .collect();
        obj(items)
    }

    /// JSON view (served by default from `GET /metrics`).
    pub fn to_json(&self, view: &ServeView) -> Json {
        let latency = {
            let map = self.lock_latency();
            let per_method: Vec<(String, Json)> =
                map.iter().map(|(name, h)| (name.clone(), Self::hist_json(h))).collect();
            obj(per_method)
        };
        let step_families = obj(trace::FAMILY_NAMES.iter().enumerate().map(|(i, name)| {
            (
                *name,
                obj([
                    (
                        "seconds",
                        num(Self::load(&self.step_family_micros[i]) as f64 / 1e6),
                    ),
                    ("steps", Json::from(Self::load(&self.step_family_steps[i]))),
                ]),
            )
        }));
        obj([
            ("uptime_secs", num(self.started.elapsed().as_secs_f64())),
            ("requests_total", Json::from(Self::load(&self.requests))),
            (
                "responses",
                obj([
                    ("2xx", Json::from(Self::load(&self.responses_2xx))),
                    ("4xx", Json::from(Self::load(&self.responses_4xx))),
                    ("5xx", Json::from(Self::load(&self.responses_5xx))),
                ]),
            ),
            (
                "listener",
                obj([
                    ("rate_limited", Json::from(Self::load(&self.rate_limited))),
                    ("auth_failures", Json::from(Self::load(&self.auth_failures))),
                ]),
            ),
            (
                "cache",
                obj([
                    ("hits", Json::from(Self::load(&self.cache_hits))),
                    ("misses", Json::from(Self::load(&self.cache_misses))),
                    ("entries", Json::from(view.cache_entries)),
                    ("bytes", Json::from(view.cache_bytes)),
                ]),
            ),
            (
                "cache_persist",
                view.persist.as_ref().map(Self::persist_json).unwrap_or(Json::Null),
            ),
            (
                "engine",
                obj([
                    ("jobs", Json::from(Self::load(&self.engine_jobs))),
                    ("phase_tiles", Json::from(Self::load(&self.phase_tiles))),
                    ("queue_depth", Json::from(view.queue_depth)),
                    ("queue_rejections", Json::from(Self::load(&self.queue_rejections))),
                    ("shard_steals", Json::from(Self::load(&self.shard_steals))),
                ]),
            ),
            ("shards", arr(view.shards.iter().map(Self::shard_json))),
            (
                "spans",
                obj([
                    ("queue_wait", Self::hist_json(&self.queue_wait)),
                    ("phase_exec", Self::hist_json(&self.phase_exec)),
                    ("tile_exec", Self::hist_json(&self.tile_exec)),
                ]),
            ),
            (
                "trace",
                obj([
                    ("keep", Json::from(view.trace_keep)),
                    ("finished_evictions", Json::from(view.trace_evictions)),
                    ("tail_kept", Json::from(Self::load(&self.trace_tail_kept))),
                ]),
            ),
            ("convergence", self.convergence_json()),
            ("step_families", step_families),
            ("latency_seconds_bucket_bounds", arr(BUCKET_BOUNDS.iter().map(|&b| num(b)))),
            ("latency", latency),
        ])
    }

    /// Prometheus text exposition (`GET /metrics?format=prometheus`, or an
    /// `Accept: text/plain` header).
    pub fn to_prometheus(&self, view: &ServeView) -> String {
        let mut out = String::new();
        let mut metric = |name: &str, kind: &str, value: u64| {
            out.push_str(&format!("# TYPE sssort_{name} {kind}\nsssort_{name} {value}\n"));
        };
        metric("requests_total", "counter", Self::load(&self.requests));
        metric("cache_hits_total", "counter", Self::load(&self.cache_hits));
        metric("cache_misses_total", "counter", Self::load(&self.cache_misses));
        metric("engine_jobs_total", "counter", Self::load(&self.engine_jobs));
        metric("phase_tiles_total", "counter", Self::load(&self.phase_tiles));
        metric("queue_rejections_total", "counter", Self::load(&self.queue_rejections));
        metric("shard_steals_total", "counter", Self::load(&self.shard_steals));
        metric("rate_limited_total", "counter", Self::load(&self.rate_limited));
        metric("auth_failures_total", "counter", Self::load(&self.auth_failures));
        metric("cache_entries", "gauge", view.cache_entries as u64);
        metric("cache_bytes", "gauge", view.cache_bytes as u64);
        metric("queue_depth", "gauge", view.queue_depth as u64);
        metric("trace_keep", "gauge", view.trace_keep);
        metric("trace_finished_evictions_total", "counter", view.trace_evictions);
        metric("trace_tail_kept_total", "counter", Self::load(&self.trace_tail_kept));
        if let Some(p) = &view.persist {
            metric("cache_persist_appends_total", "counter", p.appends);
            metric("cache_persist_replayed_total", "counter", p.replayed);
            metric("cache_persist_compactions_total", "counter", p.compactions);
            metric("cache_persist_corrupt_dropped_total", "counter", p.corrupt_dropped);
            metric("cache_persist_errors_total", "counter", p.errors);
            metric("cache_persist_file_bytes", "gauge", p.file_bytes);
        }
        if !view.shards.is_empty() {
            let families: [(&str, &str, fn(&ShardView) -> u64); 4] = [
                ("shard_jobs_total", "counter", |s: &ShardView| s.jobs),
                ("shard_queue_depth", "gauge", |s: &ShardView| s.queue_depth as u64),
                ("shard_session_memo_entries", "gauge", |s: &ShardView| s.memo_entries),
                ("shard_alive", "gauge", |s: &ShardView| s.alive as u64),
            ];
            for (name, kind, value) in families {
                out.push_str(&format!("# TYPE sssort_{name} {kind}\n"));
                for s in &view.shards {
                    out.push_str(&format!(
                        "sssort_{name}{{shard=\"{}\"}} {}\n",
                        s.id,
                        value(s)
                    ));
                }
            }
        }
        out.push_str("# TYPE sssort_responses_total counter\n");
        for (class, counter) in [
            ("2xx", &self.responses_2xx),
            ("4xx", &self.responses_4xx),
            ("5xx", &self.responses_5xx),
        ] {
            out.push_str(&format!(
                "sssort_responses_total{{class=\"{class}\"}} {}\n",
                Self::load(counter)
            ));
        }
        out.push_str(&format!(
            "# TYPE sssort_uptime_seconds gauge\nsssort_uptime_seconds {}\n",
            self.started.elapsed().as_secs_f64()
        ));
        out.push_str("# TYPE sssort_sort_duration_seconds histogram\n");
        let map = self.lock_latency();
        for (name, h) in map.iter() {
            let (buckets, sum, count) = h.snapshot();
            let mut cum = 0u64;
            for (i, &b) in buckets.iter().enumerate() {
                cum += b;
                let le = BUCKET_BOUNDS
                    .get(i)
                    .map(|b| b.to_string())
                    .unwrap_or_else(|| "+Inf".to_string());
                out.push_str(&format!(
                    "sssort_sort_duration_seconds_bucket{{method=\"{name}\",le=\"{le}\"}} {cum}\n"
                ));
            }
            out.push_str(&format!(
                "sssort_sort_duration_seconds_sum{{method=\"{name}\"}} {sum}\n"
            ));
            out.push_str(&format!(
                "sssort_sort_duration_seconds_count{{method=\"{name}\"}} {count}\n"
            ));
        }
        // Sliding-window quantiles as a separate gauge family (the
        // histogram family above stays pure `_bucket/_sum/_count`).
        out.push_str("# TYPE sssort_sort_duration_seconds_window gauge\n");
        for (name, h) in map.iter() {
            if let Some((p50, p95, p99)) = h.window_quantiles() {
                for (q, v) in [("0.5", p50), ("0.95", p95), ("0.99", p99)] {
                    out.push_str(&format!(
                        "sssort_sort_duration_seconds_window{{method=\"{name}\",quantile=\"{q}\"}} {v}\n"
                    ));
                }
            }
        }
        drop(map);
        for (name, h) in [
            ("queue_wait_seconds", &self.queue_wait),
            ("phase_exec_seconds", &self.phase_exec),
            ("tile_exec_seconds", &self.tile_exec),
        ] {
            push_histogram(&mut out, name, h);
            if let Some((p50, p95, p99)) = h.window_quantiles() {
                out.push_str(&format!("# TYPE sssort_{name}_window gauge\n"));
                for (q, v) in [("0.5", p50), ("0.95", p95), ("0.99", p99)] {
                    out.push_str(&format!(
                        "sssort_{name}_window{{quantile=\"{q}\"}} {v}\n"
                    ));
                }
            }
        }
        {
            let conv = self.lock_convergence();
            if !conv.is_empty() {
                let families: [(&str, fn(&ConvWindow) -> Option<f64>); 3] = [
                    ("convergence_mean_loss", |w: &ConvWindow| ConvWindow::mean(&w.loss)),
                    ("convergence_rejected_phase_rate", |w: &ConvWindow| {
                        ConvWindow::mean(&w.rejected_rate)
                    }),
                    ("convergence_mean_dpq", |w: &ConvWindow| ConvWindow::mean(&w.dpq)),
                ];
                for (name, value) in families {
                    out.push_str(&format!("# TYPE sssort_{name} gauge\n"));
                    for (method, w) in conv.iter() {
                        if let Some(v) = value(w) {
                            out.push_str(&format!(
                                "sssort_{name}{{method=\"{method}\"}} {v}\n"
                            ));
                        }
                    }
                }
            }
        }
        out.push_str("# TYPE sssort_step_family_seconds_total counter\n");
        for (i, fam) in trace::FAMILY_NAMES.iter().enumerate() {
            out.push_str(&format!(
                "sssort_step_family_seconds_total{{family=\"{fam}\"}} {}\n",
                Self::load(&self.step_family_micros[i]) as f64 / 1e6
            ));
        }
        out.push_str("# TYPE sssort_step_family_steps_total counter\n");
        for (i, fam) in trace::FAMILY_NAMES.iter().enumerate() {
            out.push_str(&format!(
                "sssort_step_family_steps_total{{family=\"{fam}\"}} {}\n",
                Self::load(&self.step_family_steps[i])
            ));
        }
        out
    }
}

/// Unlabeled Prometheus histogram exposition (the per-method latency map
/// has its own labeled loop above).
fn push_histogram(out: &mut String, name: &str, h: &Histogram) {
    let (buckets, sum, count) = h.snapshot();
    out.push_str(&format!("# TYPE sssort_{name} histogram\n"));
    let mut cum = 0u64;
    for (i, &b) in buckets.iter().enumerate() {
        cum += b;
        let le = BUCKET_BOUNDS
            .get(i)
            .map(|b| b.to_string())
            .unwrap_or_else(|| "+Inf".to_string());
        out.push_str(&format!("sssort_{name}_bucket{{le=\"{le}\"}} {cum}\n"));
    }
    out.push_str(&format!("sssort_{name}_sum {sum}\n"));
    out.push_str(&format!("sssort_{name}_count {count}\n"));
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn histogram_buckets_and_quantiles() {
        let h = Histogram::default();
        h.observe(0.0009); // ≤ 1 ms
        h.observe(0.003); // ≤ 5 ms
        h.observe(0.003);
        h.observe(100.0); // overflow
        let (buckets, sum, count) = h.snapshot();
        assert_eq!(count, 4);
        assert_eq!(buckets[0], 1);
        assert_eq!(buckets[2], 2);
        assert_eq!(*buckets.last().unwrap(), 1);
        assert!(sum > 100.0);
        assert_eq!(Histogram::quantile_bound(&buckets, count, 0.5), Some(0.005));
        assert_eq!(Histogram::quantile_bound(&buckets, count, 0.99), None); // +Inf
        assert_eq!(Histogram::quantile_bound(&[0; 13], 0, 0.5), None);
    }

    fn view_with_shards() -> ServeView {
        ServeView {
            cache_entries: 5,
            cache_bytes: 1234,
            queue_depth: 0,
            shards: vec![
                ShardView { id: 0, alive: true, queue_depth: 0, jobs: 7, memo_entries: 2 },
                ShardView { id: 1, alive: false, queue_depth: 3, jobs: 4, memo_entries: 1 },
            ],
            persist: Some(PersistView {
                appends: 11,
                replayed: 6,
                compactions: 1,
                corrupt_dropped: 0,
                errors: 0,
                file_bytes: 4096,
            }),
            trace_keep: 128,
            trace_evictions: 3,
        }
    }

    #[test]
    fn json_and_prometheus_views_agree_on_counters() {
        let m = Metrics::new();
        m.requests.fetch_add(3, Ordering::Relaxed);
        m.cache_hits.fetch_add(1, Ordering::Relaxed);
        m.engine_jobs.fetch_add(2, Ordering::Relaxed);
        m.phase_tiles.fetch_add(9, Ordering::Relaxed);
        m.status(200);
        m.status(404);
        m.observe("softsort", 0.002);

        let view = view_with_shards();
        let j = m.to_json(&view);
        assert_eq!(j.get("requests_total").unwrap().as_usize(), Some(3));
        assert_eq!(j.get("cache").unwrap().get("hits").unwrap().as_usize(), Some(1));
        assert_eq!(j.get("engine").unwrap().get("jobs").unwrap().as_usize(), Some(2));
        assert_eq!(j.get("engine").unwrap().get("phase_tiles").unwrap().as_usize(), Some(9));
        assert_eq!(
            j.get("latency").unwrap().get("softsort").unwrap().get("count").unwrap().as_usize(),
            Some(1)
        );

        let text = m.to_prometheus(&view);
        assert!(text.contains("sssort_requests_total 3"), "{text}");
        assert!(text.contains("sssort_cache_hits_total 1"), "{text}");
        assert!(text.contains("sssort_phase_tiles_total 9"), "{text}");
        assert!(text.contains("sssort_responses_total{class=\"2xx\"} 1"), "{text}");
        assert!(
            text.contains("sssort_sort_duration_seconds_bucket{method=\"softsort\",le=\"+Inf\"} 1"),
            "{text}"
        );
    }

    #[test]
    fn shard_gauges_and_persist_counters_appear_in_both_views() {
        let m = Metrics::new();
        m.shard_steals.fetch_add(2, Ordering::Relaxed);
        m.rate_limited.fetch_add(5, Ordering::Relaxed);
        m.auth_failures.fetch_add(1, Ordering::Relaxed);
        let view = view_with_shards();

        let j = m.to_json(&view);
        let shards = j.get("shards").unwrap().as_arr().unwrap();
        assert_eq!(shards.len(), 2);
        assert_eq!(shards[0].get("jobs").unwrap().as_usize(), Some(7));
        assert_eq!(shards[0].get("session_memo_entries").unwrap().as_usize(), Some(2));
        assert_eq!(shards[1].get("alive").unwrap().as_bool(), Some(false));
        assert_eq!(shards[1].get("queue_depth").unwrap().as_usize(), Some(3));
        let persist = j.get("cache_persist").unwrap();
        assert_eq!(persist.get("appends").unwrap().as_usize(), Some(11));
        assert_eq!(persist.get("replayed").unwrap().as_usize(), Some(6));
        assert_eq!(persist.get("compactions").unwrap().as_usize(), Some(1));
        assert_eq!(j.get("engine").unwrap().get("shard_steals").unwrap().as_usize(), Some(2));
        assert_eq!(j.get("listener").unwrap().get("rate_limited").unwrap().as_usize(), Some(5));
        assert_eq!(j.get("listener").unwrap().get("auth_failures").unwrap().as_usize(), Some(1));

        let text = m.to_prometheus(&view);
        assert!(text.contains("sssort_shard_jobs_total{shard=\"0\"} 7"), "{text}");
        assert!(text.contains("sssort_shard_jobs_total{shard=\"1\"} 4"), "{text}");
        assert!(text.contains("sssort_shard_queue_depth{shard=\"1\"} 3"), "{text}");
        assert!(text.contains("sssort_shard_session_memo_entries{shard=\"0\"} 2"), "{text}");
        assert!(text.contains("sssort_shard_alive{shard=\"1\"} 0"), "{text}");
        assert!(text.contains("sssort_cache_persist_appends_total 11"), "{text}");
        assert!(text.contains("sssort_cache_persist_replayed_total 6"), "{text}");
        assert!(text.contains("sssort_cache_persist_file_bytes 4096"), "{text}");
        assert!(text.contains("sssort_shard_steals_total 2"), "{text}");
        assert!(text.contains("sssort_rate_limited_total 5"), "{text}");
        assert!(text.contains("sssort_auth_failures_total 1"), "{text}");

        // Without persistence the JSON slot is null and the Prometheus
        // family is absent entirely.
        let bare = ServeView { persist: None, ..view_with_shards() };
        let j = m.to_json(&bare);
        assert!(matches!(j.get("cache_persist"), Some(Json::Null)));
        assert!(!m.to_prometheus(&bare).contains("cache_persist"), "no spurious family");
    }

    fn span_rec(name: &'static str, dur_us: u64, steps: Option<u64>) -> trace::SpanRecord {
        let mut attrs: trace::Attrs = [None; trace::MAX_ATTRS];
        if let Some(s) = steps {
            attrs[0] = Some(("steps", trace::AttrValue::U64(s)));
        }
        trace::SpanRecord {
            trace_id: 1,
            span_id: 2,
            parent_id: 0,
            name,
            start_us: 0,
            dur_us,
            tid: 1,
            attrs,
        }
    }

    #[test]
    fn span_histograms_and_family_totals_export() {
        let m = Metrics::new();
        m.queue_wait.observe(0.002);
        m.phase_exec.observe(0.01);
        // Trace-derived telemetry: one phase, one tile, two step families,
        // and a request span the walker must ignore.
        let t = trace::FinishedTrace {
            trace_id: 1,
            spans: vec![
                span_rec("phase", 10_000, None),
                span_rec("tile", 4_000, None),
                span_rec("sss_step", 2_000, Some(32)),
                span_rec("adam_step", 1_000, Some(32)),
                span_rec("request", 20_000, None),
            ],
            dropped: 0,
        };
        m.observe_trace(&t);

        let view = ServeView::default();
        let j = m.to_json(&view);
        let spans = j.get("spans").unwrap();
        assert_eq!(spans.get("queue_wait").unwrap().get("count").unwrap().as_usize(), Some(1));
        assert_eq!(spans.get("phase_exec").unwrap().get("count").unwrap().as_usize(), Some(2));
        assert_eq!(spans.get("tile_exec").unwrap().get("count").unwrap().as_usize(), Some(1));
        let fam = j.get("step_families").unwrap().get("sss_step").unwrap();
        assert_eq!(fam.get("steps").unwrap().as_usize(), Some(32));
        assert!(fam.get("seconds").unwrap().as_f64().unwrap() > 0.0);

        let text = m.to_prometheus(&view);
        assert!(text.contains("sssort_queue_wait_seconds_count 1"), "{text}");
        assert!(text.contains("sssort_phase_exec_seconds_count 2"), "{text}");
        assert!(text.contains("sssort_tile_exec_seconds_bucket{le=\"+Inf\"} 1"), "{text}");
        assert!(
            text.contains("sssort_step_family_steps_total{family=\"sss_step\"} 32"),
            "{text}"
        );
        assert!(
            text.contains("sssort_step_family_seconds_total{family=\"adam_step\"} 0.001"),
            "{text}"
        );
    }

    #[test]
    fn window_quantiles_are_exact_over_recent_samples() {
        let h = Histogram::default();
        assert_eq!(h.window_quantiles(), None, "empty window has no quantiles");
        for i in 1..=100 {
            h.observe(i as f64 / 1000.0); // 1ms..100ms
        }
        let (p50, p95, p99) = h.window_quantiles().unwrap();
        assert!((p50 - 0.0505).abs() < 0.002, "p50={p50}");
        assert!((p95 - 0.095).abs() < 0.002, "p95={p95}");
        assert!((p99 - 0.099).abs() < 0.002, "p99={p99}");
        // The window slides: WINDOW_CAP large samples push the small ones
        // out, so p50 tracks the recent distribution, not the lifetime one.
        for _ in 0..WINDOW_CAP {
            h.observe(2.0);
        }
        let (p50, _, p99) = h.window_quantiles().unwrap();
        assert_eq!(p50, 2.0);
        assert_eq!(p99, 2.0);
    }

    #[test]
    fn percentiles_export_in_json_and_prometheus() {
        let m = Metrics::new();
        for i in 0..50 {
            m.queue_wait.observe(0.001 + i as f64 * 0.0001);
            m.observe("shuffle-softsort", 0.01 + i as f64 * 0.001);
        }
        let view = ServeView::default();
        let j = m.to_json(&view);
        let qw = j.get("spans").unwrap().get("queue_wait").unwrap();
        for key in ["p50_ms", "p95_ms", "p99_ms"] {
            assert!(qw.get(key).unwrap().as_f64().unwrap() > 0.0, "{key}");
        }
        let lat = j.get("latency").unwrap().get("shuffle-softsort").unwrap();
        assert!(lat.get("p95_ms").unwrap().as_f64().unwrap() > 0.0);

        let text = m.to_prometheus(&view);
        assert!(text.contains("sssort_queue_wait_seconds_window{quantile=\"0.5\"}"), "{text}");
        assert!(text.contains("sssort_queue_wait_seconds_window{quantile=\"0.99\"}"), "{text}");
        assert!(
            text.contains(
                "sssort_sort_duration_seconds_window{method=\"shuffle-softsort\",quantile=\"0.95\"}"
            ),
            "{text}"
        );
        // Untouched histograms export no quantile lines at all.
        assert!(!text.contains("sssort_tile_exec_seconds_window"), "{text}");
    }

    #[test]
    fn convergence_windows_aggregate_per_method() {
        let m = Metrics::new();
        m.observe_convergence("shuffle-softsort", 0.2, 0.125, 0.9);
        m.observe_convergence("shuffle-softsort", 0.4, 0.375, 0.7);
        // DPQ not computed for this run: loss still counts.
        m.observe_convergence("softsort", 0.1, 0.0, f64::NAN);

        let view = ServeView::default();
        let j = m.to_json(&view);
        let conv = j.get("convergence").unwrap();
        let sss = conv.get("shuffle-softsort").unwrap();
        assert_eq!(sss.get("runs").unwrap().as_usize(), Some(2));
        assert!((sss.get("mean_loss").unwrap().as_f64().unwrap() - 0.3).abs() < 1e-9);
        assert!((sss.get("rejected_phase_rate").unwrap().as_f64().unwrap() - 0.25).abs() < 1e-9);
        assert!((sss.get("mean_dpq").unwrap().as_f64().unwrap() - 0.8).abs() < 1e-9);
        let ss = conv.get("softsort").unwrap();
        assert!(matches!(ss.get("mean_dpq"), Some(Json::Null)), "NaN DPQ is skipped");
        assert!((ss.get("mean_loss").unwrap().as_f64().unwrap() - 0.1).abs() < 1e-9);

        let text = m.to_prometheus(&view);
        assert!(
            text.contains("sssort_convergence_mean_loss{method=\"shuffle-softsort\"} 0.3"),
            "{text}"
        );
        assert!(
            text.contains(
                "sssort_convergence_rejected_phase_rate{method=\"shuffle-softsort\"} 0.25"
            ),
            "{text}"
        );
        assert!(
            !text.contains("sssort_convergence_mean_dpq{method=\"softsort\"}"),
            "no DPQ line for a method that never computed one: {text}"
        );
    }

    #[test]
    fn trace_lru_counters_export() {
        let m = Metrics::new();
        m.trace_tail_kept.fetch_add(4, Ordering::Relaxed);
        let view = view_with_shards();
        let j = m.to_json(&view);
        let tr = j.get("trace").unwrap();
        assert_eq!(tr.get("keep").unwrap().as_usize(), Some(128));
        assert_eq!(tr.get("finished_evictions").unwrap().as_usize(), Some(3));
        assert_eq!(tr.get("tail_kept").unwrap().as_usize(), Some(4));
        let text = m.to_prometheus(&view);
        assert!(text.contains("sssort_trace_keep 128"), "{text}");
        assert!(text.contains("sssort_trace_finished_evictions_total 3"), "{text}");
        assert!(text.contains("sssort_trace_tail_kept_total 4"), "{text}");
    }
}
