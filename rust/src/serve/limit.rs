//! Per-client token-bucket rate limiting for the serve listener.
//!
//! One bucket per peer IP: capacity `burst = max(2·rate, 1)` tokens,
//! refilled continuously at `rate` tokens/second. Each request spends one
//! token; an empty bucket answers `429 Too Many Requests`. The shape is
//! deliberately forgiving — a client may burst to twice its steady rate
//! after a quiet spell, and a single misbehaving client never starves the
//! others (its bucket, its problem).
//!
//! `/healthz` is exempt at the call site: load-balancer probes must never
//! be throttled into marking a healthy instance down.

use std::collections::HashMap;
use std::net::IpAddr;
use std::sync::{Mutex, MutexGuard};
use std::time::Instant;

/// Stop tracking peers beyond this many buckets; on overflow, buckets idle
/// for a minute are dropped first (a refilled-idle bucket reconstructs
/// identically, so forgetting one is harmless).
const MAX_CLIENTS: usize = 4096;
const IDLE_EVICT_SECS: u64 = 60;

struct Bucket {
    tokens: f64,
    last: Instant,
}

pub struct RateLimiter {
    rate: f64,
    burst: f64,
    buckets: Mutex<HashMap<IpAddr, Bucket>>,
}

impl RateLimiter {
    /// `rate_per_sec` is the steady-state allowance (an integer so the
    /// config stays `Eq`); callers gate on `rate_limit > 0` before
    /// constructing one.
    pub fn new(rate_per_sec: u64) -> Self {
        let rate = rate_per_sec as f64;
        RateLimiter { rate, burst: (2.0 * rate).max(1.0), buckets: Mutex::new(HashMap::new()) }
    }

    fn lock_buckets(&self) -> MutexGuard<'_, HashMap<IpAddr, Bucket>> {
        // Bucket math can't panic, but recover rather than propagate: rate
        // limiting must never be the thing that takes the listener down.
        self.buckets.lock().unwrap_or_else(|poisoned| {
            self.buckets.clear_poison();
            poisoned.into_inner()
        })
    }

    /// Spend one token from `peer`'s bucket at time `now` (injected for
    /// testability). `true` = admit, `false` = throttle.
    pub fn allow(&self, peer: IpAddr, now: Instant) -> bool {
        let mut buckets = self.lock_buckets();
        if buckets.len() >= MAX_CLIENTS && !buckets.contains_key(&peer) {
            let idle = std::time::Duration::from_secs(IDLE_EVICT_SECS);
            buckets.retain(|_, b| now.saturating_duration_since(b.last) < idle);
        }
        let bucket = buckets
            .entry(peer)
            .or_insert(Bucket { tokens: self.burst, last: now });
        let dt = now.saturating_duration_since(bucket.last).as_secs_f64();
        bucket.tokens = (bucket.tokens + dt * self.rate).min(self.burst);
        bucket.last = now;
        if bucket.tokens >= 1.0 {
            bucket.tokens -= 1.0;
            true
        } else {
            false
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::net::Ipv4Addr;
    use std::time::Duration;

    fn ip(last: u8) -> IpAddr {
        IpAddr::V4(Ipv4Addr::new(10, 0, 0, last))
    }

    #[test]
    fn burst_then_throttle_then_refill() {
        let limiter = RateLimiter::new(2); // burst 4
        let t0 = Instant::now();
        for i in 0..4 {
            assert!(limiter.allow(ip(1), t0), "burst request {i} admitted");
        }
        assert!(!limiter.allow(ip(1), t0), "empty bucket throttles");
        // 1 second at rate 2 → two tokens back.
        let t1 = t0 + Duration::from_secs(1);
        assert!(limiter.allow(ip(1), t1));
        assert!(limiter.allow(ip(1), t1));
        assert!(!limiter.allow(ip(1), t1));
    }

    #[test]
    fn buckets_are_per_client() {
        let limiter = RateLimiter::new(1); // burst 2
        let t0 = Instant::now();
        assert!(limiter.allow(ip(1), t0));
        assert!(limiter.allow(ip(1), t0));
        assert!(!limiter.allow(ip(1), t0), "client 1 exhausted");
        assert!(limiter.allow(ip(2), t0), "client 2 unaffected");
    }

    #[test]
    fn refill_caps_at_burst() {
        let limiter = RateLimiter::new(1); // burst 2
        let t0 = Instant::now();
        assert!(limiter.allow(ip(1), t0));
        // A long quiet spell refills to burst (2), not unbounded.
        let t1 = t0 + Duration::from_secs(3600);
        assert!(limiter.allow(ip(1), t1));
        assert!(limiter.allow(ip(1), t1));
        assert!(!limiter.allow(ip(1), t1));
    }
}
