//! Hand-rolled CLI argument parsing (clap is unavailable offline).
//!
//! Grammar: `sssort <command> [positional...] [--flag] [--key value] [k=v]`.
//! `k=v` pairs are collected as config overrides.

use std::collections::BTreeMap;

use anyhow::{anyhow, bail, Result};

#[derive(Debug, Default)]
pub struct ParsedArgs {
    pub command: String,
    pub positional: Vec<String>,
    pub flags: Vec<String>,
    pub options: BTreeMap<String, String>,
    /// `key=value` config overrides, applied in order.
    pub overrides: Vec<(String, String)>,
}

impl ParsedArgs {
    pub fn parse(args: impl IntoIterator<Item = String>) -> Result<ParsedArgs> {
        let mut it = args.into_iter();
        let mut out = ParsedArgs::default();
        out.command = it.next().unwrap_or_else(|| "help".to_string());
        let mut pending_key: Option<String> = None;
        for a in it {
            if let Some(key) = pending_key.take() {
                out.options.insert(key, a);
                continue;
            }
            if let Some(stripped) = a.strip_prefix("--") {
                // `--key=value`, boolean `--flag`, or `--key value`.
                if let Some((k, v)) = stripped.split_once('=') {
                    out.options.insert(k.to_string(), v.to_string());
                } else if KNOWN_VALUE_OPTS.contains(&stripped) {
                    pending_key = Some(stripped.to_string());
                } else {
                    out.flags.push(stripped.to_string());
                }
            } else if let Some((k, v)) = a.split_once('=') {
                out.overrides.push((k.to_string(), v.to_string()));
            } else {
                out.positional.push(a);
            }
        }
        if let Some(k) = pending_key {
            bail!("option --{k} expects a value");
        }
        Ok(out)
    }

    pub fn flag(&self, name: &str) -> bool {
        self.flags.iter().any(|f| f == name)
    }

    pub fn opt(&self, name: &str) -> Option<&str> {
        self.options.get(name).map(|s| s.as_str())
    }

    pub fn opt_usize(&self, name: &str, default: usize) -> Result<usize> {
        match self.opt(name) {
            None => Ok(default),
            Some(v) => v.parse().map_err(|_| anyhow!("--{name} must be an integer")),
        }
    }

    pub fn pos(&self, i: usize) -> Option<&str> {
        self.positional.get(i).map(|s| s.as_str())
    }
}

/// Options that always take a value (everything else after `--` is a flag).
const KNOWN_VALUE_OPTS: &[&str] = &[
    "n", "grid", "method", "out", "seed", "config", "artifacts", "dataset",
    "bits", "entropy", "scene-seed", "clusters", "dims", "batch", "workers",
    "backend", "threads", "simd", "addr", "cache-mb", "tile-n", "shards",
    "cache-file", "rate-limit", "auth-token", "trace-file", "profile-file",
    "trace-sample", "trace-keep", "tile-plan", "trace-tail-ms",
];

pub const USAGE: &str = "\
sssort — ShuffleSoftSort permutation-learning coordinator

USAGE:
  sssort sort    [--method NAME] [--grid HxW] [--dataset colors|features]
                 [--backend auto|native|pjrt] [--threads T] [--tile-n T]
                 [--tile-plan banded|snake|overlapped] [--pyramid]
                 [--simd auto|off|sse2|avx2] [--seed S] [--batch K]
                 [--workers W] [--out dir] [--trace-file PATH]
                 [--profile-file PATH] [k=v ...]
                 sort dataset(s), report DPQ (batch >1 fans out across threads)
  sssort serve   [--addr HOST:PORT] [--workers W] [--cache-mb MB]
                 [--shards K] [--cache-file PATH] [--rate-limit R]
                 [--auth-token TOKEN] [--backend B] [--threads T]
                 [--trace-sample K] [--trace-keep N] [--trace-tail-ms T]
                 [--artifacts dir] [k=v overrides]
                 HTTP service over the engine: POST /v1/sort, /v1/sort_batch,
                 GET /v1/methods, /healthz, /metrics (see README \u{a7}Serving).
                 --shards K runs K engine hosts with hashed job affinity;
                 --cache-file persists the result cache across restarts;
                 --rate-limit R throttles each client to R req/s (2x burst);
                 --auth-token requires `Authorization: Bearer TOKEN`.
  sssort sog     [--n N] [--grid HxW] [--bits B] [--backend B] [--out dir]
                 run the Self-Organizing-Gaussians pipeline (Fig. 6)
  sssort inspect [--artifacts dir]                        list AOT artifacts
  sssort help                                             this text

Config overrides are bare k=v pairs, e.g. `phases=300 lr=0.3 shuffle=random`;
`backend=native` works as an override pair too. The default backend is
`auto`: use the AOT artifacts when artifacts/manifest.json exists, else run
the learned methods on the pure-Rust native backend (no artifacts needed).
`--threads T` (or a `threads=T` pair) sizes the native step session's
worker pool; 0 = backend default. Results never depend on it.
`--simd L` (or a `simd=L` pair) picks the native step-kernel level: `auto`
(default) uses the best instruction set detected at runtime, `off` forces
the scalar bit-exactness oracle (README section Performance).
`--tile-n T` (or `tile_n=T` / `tiles=B`) enables tiled phase execution for
shuffle-softsort: independent per-tile SoftSort solves of ~T cells keep
per-step cost and memory at O(tile_n^2) instead of O(N^2) — use it for
large grids (README section Scaling). `--tile-plan P` (or `tile_plan=P`)
picks how tiles cut the grid: `banded` (default, fixed row bands),
`snake` (boustrophedon chains crossing row seams) or `overlapped`
(phase-alternating half-tile-offset bands, so seams shift every phase).
`--pyramid` (or `pyramid=true`) switches to the coarse-to-fine executor:
sort tile centroids on a coarse grid, relocate whole tiles, refine
recursively — the path for million-item grids (README section Scaling).
For `serve`, k=v pairs configure the
service (queue_depth, max_body_bytes, arranged_max_n, trace, ...).
`--trace-file PATH` (sort) records the run's span tree — phases, tiles,
step kernels — as Chrome trace-event JSON; open it in chrome://tracing.
`--profile-file PATH` (sort) folds the same span tree into collapsed
stacks (`path;to;span self_us` per line) for flamegraph.pl / speedscope.
For `serve`, `--trace-sample K` traces 1 in K requests (0 disables
tracing, 1 traces everything — the default) and `--trace-keep N` sizes
the finished-trace LRU behind GET /v1/trace/<id>. `--trace-tail-ms T`
adds tail-based sampling: a request the head sampler would drop is still
traced speculatively and kept when it runs longer than T ms (0, the
default, disables tail sampling).
";

/// Full usage text: the static grammar plus the live method list from the
/// registry (so `help` and unknown-command errors always reflect what
/// `--method` actually accepts).
pub fn usage() -> String {
    let reg = crate::api::MethodRegistry::new();
    let mut text = String::from(USAGE);
    text.push_str("\nMethods (--method NAME; aliases in parentheses):\n");
    for spec in reg.specs() {
        let alias = if spec.aliases.is_empty() {
            String::new()
        } else {
            format!(" ({})", spec.aliases.join(", "))
        };
        let kind = match spec.kind {
            crate::api::MethodKind::Learned => "learned",
            crate::api::MethodKind::Heuristic => "heuristic",
        };
        text.push_str(&format!(
            "  {:<24} {:<9} {}\n",
            format!("{}{alias}", spec.name),
            kind,
            spec.summary
        ));
    }
    text
}

/// Parse "HxW" grid syntax.
pub fn parse_grid(s: &str) -> Result<(usize, usize)> {
    let (h, w) = s
        .split_once(['x', 'X'])
        .ok_or_else(|| anyhow!("grid must be HxW, got '{s}'"))?;
    Ok((h.parse()?, w.parse()?))
}

#[cfg(test)]
mod tests {
    use super::*;

    fn parse(v: &[&str]) -> ParsedArgs {
        ParsedArgs::parse(v.iter().map(|s| s.to_string())).unwrap()
    }

    #[test]
    fn parses_command_options_flags_overrides() {
        let a = parse(&[
            "sort", "--grid", "16x16", "--method=sss", "--full", "phases=12", "lr=0.3",
        ]);
        assert_eq!(a.command, "sort");
        assert_eq!(a.opt("grid"), Some("16x16"));
        assert_eq!(a.opt("method"), Some("sss"));
        assert!(a.flag("full"));
        assert_eq!(a.overrides, vec![("phases".into(), "12".into()), ("lr".into(), "0.3".into())]);
    }

    #[test]
    fn missing_value_is_error() {
        assert!(ParsedArgs::parse(vec!["sort".to_string(), "--grid".to_string()]).is_err());
    }

    #[test]
    fn grid_syntax() {
        assert_eq!(parse_grid("32x32").unwrap(), (32, 32));
        assert_eq!(parse_grid("8X16").unwrap(), (8, 16));
        assert!(parse_grid("64").is_err());
    }

    #[test]
    fn defaults() {
        let a = parse(&["inspect"]);
        assert_eq!(a.command, "inspect");
        assert_eq!(a.opt_usize("n", 1024).unwrap(), 1024);
    }

    #[test]
    fn batch_and_workers_take_values() {
        let a = parse(&["sort", "--batch", "4", "--workers", "2"]);
        assert_eq!(a.opt_usize("batch", 1).unwrap(), 4);
        assert_eq!(a.opt_usize("workers", 1).unwrap(), 2);
        assert!(a.positional.is_empty());
    }

    #[test]
    fn backend_takes_a_value() {
        let a = parse(&["sort", "--backend", "native", "--method", "sss"]);
        assert_eq!(a.opt("backend"), Some("native"));
        assert!(a.positional.is_empty());
        assert!(usage().contains("--backend"));
    }

    #[test]
    fn threads_takes_a_value() {
        let a = parse(&["sort", "--threads", "4"]);
        assert_eq!(a.opt_usize("threads", 0).unwrap(), 4);
        assert!(a.positional.is_empty());
        assert!(usage().contains("--threads"));
    }

    #[test]
    fn simd_takes_a_value() {
        let a = parse(&["sort", "--simd", "off", "--method", "sss"]);
        assert_eq!(a.opt("simd"), Some("off"));
        assert!(a.positional.is_empty());
        assert!(usage().contains("--simd"));
    }

    #[test]
    fn tile_n_takes_a_value() {
        let a = parse(&["sort", "--tile-n", "512", "--method", "sss"]);
        assert_eq!(a.opt_usize("tile-n", 0).unwrap(), 512);
        assert!(a.positional.is_empty());
        assert!(usage().contains("--tile-n"));
    }

    #[test]
    fn tile_plan_takes_a_value_and_pyramid_is_a_flag() {
        let a = parse(&["sort", "--tile-plan", "snake", "--pyramid", "--method", "sss"]);
        assert_eq!(a.opt("tile-plan"), Some("snake"));
        assert!(a.flag("pyramid"));
        assert!(a.positional.is_empty());
        assert!(usage().contains("--tile-plan"));
        assert!(usage().contains("--pyramid"));
    }

    #[test]
    fn trace_tail_ms_takes_a_value() {
        let a = parse(&["serve", "--trace-tail-ms", "250"]);
        assert_eq!(a.opt_usize("trace-tail-ms", 0).unwrap(), 250);
        assert!(a.positional.is_empty());
        assert!(usage().contains("--trace-tail-ms"));
    }

    #[test]
    fn serve_options_take_values() {
        let a = parse(&[
            "serve", "--addr", "127.0.0.1:0", "--workers", "2", "--cache-mb", "16",
            "queue_depth=8",
        ]);
        assert_eq!(a.command, "serve");
        assert_eq!(a.opt("addr"), Some("127.0.0.1:0"));
        assert_eq!(a.opt_usize("workers", 0).unwrap(), 2);
        assert_eq!(a.opt_usize("cache-mb", 0).unwrap(), 16);
        assert_eq!(a.overrides, vec![("queue_depth".into(), "8".into())]);
        assert!(a.positional.is_empty());
        assert!(usage().contains("sssort serve"));
    }

    #[test]
    fn serve_shard_and_persistence_options_take_values() {
        let a = parse(&[
            "serve", "--shards", "4", "--cache-file", "/tmp/spill", "--rate-limit",
            "25", "--auth-token", "s3cret",
        ]);
        assert_eq!(a.opt_usize("shards", 1).unwrap(), 4);
        assert_eq!(a.opt("cache-file"), Some("/tmp/spill"));
        assert_eq!(a.opt_usize("rate-limit", 0).unwrap(), 25);
        assert_eq!(a.opt("auth-token"), Some("s3cret"));
        assert!(a.positional.is_empty());
        for flag in ["--shards", "--cache-file", "--rate-limit", "--auth-token"] {
            assert!(usage().contains(flag), "usage() missing {flag}");
        }
    }

    #[test]
    fn trace_file_takes_a_value() {
        let a = parse(&["sort", "--trace-file", "/tmp/trace.json", "--method", "sss"]);
        assert_eq!(a.opt("trace-file"), Some("/tmp/trace.json"));
        assert!(a.positional.is_empty());
        assert!(usage().contains("--trace-file"));
    }

    #[test]
    fn profile_file_takes_a_value() {
        let a = parse(&["sort", "--profile-file", "/tmp/p.folded", "--method", "sss"]);
        assert_eq!(a.opt("profile-file"), Some("/tmp/p.folded"));
        assert!(a.positional.is_empty());
        assert!(usage().contains("--profile-file"));
    }

    #[test]
    fn serve_sampling_options_take_values() {
        let a = parse(&["serve", "--trace-sample", "8", "--trace-keep", "256"]);
        assert_eq!(a.opt_usize("trace-sample", 1).unwrap(), 8);
        assert_eq!(a.opt_usize("trace-keep", 128).unwrap(), 256);
        assert!(a.positional.is_empty());
        for flag in ["--trace-sample", "--trace-keep"] {
            assert!(usage().contains(flag), "usage() missing {flag}");
        }
    }

    #[test]
    fn usage_lists_registry_methods() {
        let text = usage();
        for name in crate::api::MethodRegistry::new().names() {
            assert!(text.contains(name), "usage() missing method {name}");
        }
        assert!(text.contains("(sss, shufflesoftsort)"));
    }
}
