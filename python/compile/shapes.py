"""Artifact registry: every (method, shape) combination shipped to Rust.

HLO is shape-static, so each problem size is its own artifact. The set below
covers every experiment in DESIGN.md §4; the Rust runtime discovers them via
``artifacts/manifest.json``.

Kissing rank M follows [4]'s kissing-number rule (kissing_number(M) ≥ N);
the paper's Table 2 entry 2·1024·13 = 26624 pins M(1024) = 13.
"""

from __future__ import annotations

from dataclasses import dataclass, field
from typing import List, Tuple

# (max N covered, M) — classic kissing numbers K(M):
# K(8)=240, K(9)=306, K(10)=500, K(11)=582, K(12)=840, K(13)=1154, K(16)=4320
_KISSING_TABLE: List[Tuple[int, int]] = [
    (240, 8), (306, 9), (500, 10), (582, 11), (840, 12), (1154, 13), (4320, 16),
]


def kissing_rank(n: int) -> int:
    """Smallest M from the table with kissing_number(M) ≥ N."""
    for max_n, m in _KISSING_TABLE:
        if n <= max_n:
            return m
    raise ValueError(f"no tabulated kissing rank covers N={n}")


@dataclass(frozen=True)
class ArtifactSpec:
    method: str              # "sss" | "gs" | "gs_probe" | "kiss"
    n: int
    d: int
    h: int
    w: int
    m: int = 0               # kissing rank (kiss only)
    block: int = 32          # pallas row-block (sss only)

    @property
    def name(self) -> str:
        if self.method == "kiss":
            return f"kiss_step_n{self.n}_m{self.m}_d{self.d}"
        if self.method == "gs_probe":
            return f"gs_probe_n{self.n}"
        return f"{self.method}_step_n{self.n}_d{self.d}_h{self.h}"

    @property
    def param_count(self) -> int:
        return {"sss": self.n, "gs": self.n * self.n,
                "gs_probe": self.n * self.n,
                "kiss": 2 * self.n * self.m}[self.method]


def _sss(n, d, h, w, block=32):
    return ArtifactSpec("sss", n, d, h, w, block=block)


def _gs(n, d, h, w):
    return ArtifactSpec("gs", n, d, h, w)


def _gsp(n):
    return ArtifactSpec("gs_probe", n, 0, 0, 0)


def _kiss(n, d, h, w):
    return ArtifactSpec("kiss", n, d, h, w, m=kissing_rank(n))


ARTIFACTS: List[ArtifactSpec] = [
    # --- ShuffleSoftSort / SoftSort (shared step) -------------------------
    _sss(16, 3, 1, 16, block=8),    # Fig. 3 1-D toy
    _sss(64, 3, 1, 64),             # 1-D chain, integration tests
    _sss(64, 3, 8, 8),              # small grid, integration tests
    _sss(256, 3, 16, 16),           # quickstart
    _sss(1024, 3, 32, 32),          # Table 2 / Fig. 1 headline
    _sss(4096, 3, 64, 64),          # scaling
    _sss(256, 50, 16, 16),          # Fig. 5 features (small)
    _sss(1024, 50, 32, 32),         # Fig. 5 features
    _sss(1024, 14, 32, 32),         # SOG attributes (small)
    _sss(4096, 14, 64, 64),         # SOG attributes (end-to-end example)
    # --- Gumbel-Sinkhorn ---------------------------------------------------
    _gs(64, 3, 8, 8),
    _gs(256, 3, 16, 16),
    _gs(1024, 3, 32, 32),
    _gsp(64), _gsp(256), _gsp(1024),
    # --- Kissing ------------------------------------------------------------
    _kiss(64, 3, 8, 8),
    _kiss(256, 3, 16, 16),
    _kiss(1024, 3, 32, 32),
    _kiss(4096, 3, 64, 64),
]
