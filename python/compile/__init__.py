# Build-time compile package: L1 Pallas kernels, L2 JAX model/losses,
# AOT lowering to HLO text. Never imported at request time.
