"""Layer-2 JAX model: one training-step function per permutation-learning
method, all lowered AOT to HLO text and executed from Rust.

Methods (paper §II):

* ``make_sss_step``      — SoftSort / ShuffleSoftSort shared step. The
  difference between the two methods is pure L3 policy (identity shuffle +
  one phase vs. Algorithm 1's shuffled phases); the compute step is
  identical. Forward goes through the L1 Pallas kernel via a custom_vjp
  whose backward is the O(C·N)-memory chunked oracle.
* ``make_gs_step`` / ``make_gs_probe`` — Gumbel-Sinkhorn baseline [11].
  Gumbel noise is sampled Rust-side and passed in, keeping the artifact a
  pure function. The probe artifact returns the dense P for the final
  JV-based hard extraction (only ever called O(1) times).
* ``make_kiss_step``     — "Kissing to Find a Match" low-rank baseline [4]:
  P ≈ row-softmax(scale · V̂ Ŵᵀ / τ) with row-normalized V̂, Ŵ.

Every step returns (loss, grads…, sort_idx, colsum[, y]); parameters live in
Rust (the optimizer is Rust-side Adam), so steps are stateless.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from . import losses
from .kernels.ref import softsort_apply_chunked, softsort_matrix
from .kernels.softsort import softsort_apply_pallas
from .primitives import float0_zeros, take0

KISS_SCALE = 30.0
SINKHORN_ITERS = 20


# --------------------------------------------------------------------------
# SoftSort-apply with Pallas forward and chunked backward.
# --------------------------------------------------------------------------

@functools.partial(jax.custom_vjp, nondiff_argnums=(3,))
def softsort_apply(w, x, tau, block: int = 32):
    """(y, sort_idx, colsum) — Pallas forward, memory-bounded backward."""
    return softsort_apply_pallas(w, x, tau, block=block)


def _ssa_fwd(w, x, tau, block):
    return softsort_apply_pallas(w, x, tau, block=block), (w, x, tau)


def _ssa_bwd(block, res, ct):
    w, x, tau = res
    ct_y, _ct_idx, ct_cs = ct     # sort_idx is integer → float0 cotangent

    def f(w_, x_):
        return softsort_apply_chunked(w_, x_, tau)

    _, vjp = jax.vjp(f, w, x)
    gw, gx = vjp((ct_y.astype(x.dtype), ct_cs))
    return gw, gx, jnp.zeros((), dtype=tau.dtype)


softsort_apply.defvjp(_ssa_fwd, _ssa_bwd)


# --------------------------------------------------------------------------
# ShuffleSoftSort / SoftSort step (Algorithm 1 inner iteration).
# --------------------------------------------------------------------------

def make_sss_step(n: int, d: int, h: int, w_grid: int, block: int = 32):
    """Build the jittable step for an (N, d) problem on an H×W grid.

    Inputs : w f32[N], x_shuf f32[N,d], inv_idx i32[N], tau f32[], norm f32[]
    Outputs: loss f32[], grad f32[N], sort_idx i32[N], colsum f32[N], y f32[N,d]

    ``inv_idx`` is the inverse of the phase's shuffle permutation; the loss
    is evaluated on the reverse-shuffled soft output (Algorithm 1:
    ``x_sort_soft[shuf_idx] = x_sort_soft``), implemented as the
    grad-safe gather ``take0(y, inv_idx)``.
    """
    assert n == h * w_grid, f"grid {h}x{w_grid} != N={n}"

    def step(w, x_shuf, inv_idx, tau, norm):
        def loss_fn(w_):
            y, sort_idx, colsum = softsort_apply(w_, x_shuf, tau, block)
            y_grid = take0(y, inv_idx).reshape(h, w_grid, d)
            loss = losses.combined(y_grid, colsum, x_shuf, y, norm)
            return loss, (sort_idx, colsum, y)

        (loss, (sort_idx, colsum, y)), grad = jax.value_and_grad(
            loss_fn, has_aux=True)(w)
        return loss, grad, sort_idx, colsum, y

    return step


# --------------------------------------------------------------------------
# Gumbel-Sinkhorn baseline.
# --------------------------------------------------------------------------

def _sinkhorn_log(log_alpha, iters: int = SINKHORN_ITERS):
    """Log-space Sinkhorn normalization → (approximately) doubly stochastic.

    Unrolled python loop: fixed small iteration count, grad-safe in this
    jax build (fori_loop reverse-mode is fine too, but unrolling keeps the
    HLO free of dynamic-slice gathers — see primitives.py).
    """
    for _ in range(iters):
        log_alpha = log_alpha - jax.nn.logsumexp(log_alpha, axis=1, keepdims=True)
        log_alpha = log_alpha - jax.nn.logsumexp(log_alpha, axis=0, keepdims=True)
    return jnp.exp(log_alpha)


def make_gs_step(n: int, d: int, h: int, w_grid: int):
    """Gumbel-Sinkhorn training step.

    Inputs : logits f32[N,N], x f32[N,d], gumbel f32[N,N], tau f32[], norm f32[]
    Outputs: loss f32[], grad f32[N,N], sort_idx i32[N], colsum f32[N]
    """
    assert n == h * w_grid

    def step(logits, x, gumbel, tau, norm):
        def loss_fn(logits_):
            p = _sinkhorn_log((logits_ + gumbel) / tau)
            y = p @ x
            y_grid = y.reshape(h, w_grid, d)
            colsum = jnp.sum(p, axis=0)
            # Sinkhorn already enforces stochasticity; keep the σ term as
            # in [2]'s gradient-based layout objective.
            loss = (losses.l_nbr(y_grid, norm)
                    + losses.LAMBDA_SIGMA * losses.l_sigma(x, y))
            return loss, (p, colsum)

        (loss, (p, colsum)), grad = jax.value_and_grad(
            loss_fn, has_aux=True)(logits)
        sort_idx = jnp.argmax(p, axis=1).astype(jnp.int32)
        return loss, grad, sort_idx, colsum

    return step


def make_gs_probe(n: int):
    """Return the dense doubly-stochastic P for final (JV) extraction."""

    def probe(logits, gumbel, tau):
        return _sinkhorn_log((logits + gumbel) / tau)

    return probe


# --------------------------------------------------------------------------
# Kissing-to-Find-a-Match baseline (low-rank factorization).
# --------------------------------------------------------------------------

def make_kiss_step(n: int, m: int, d: int, h: int, w_grid: int,
                   scale: float = KISS_SCALE):
    """Low-rank step: P ≈ row-softmax(scale · V̂ Ŵᵀ / τ), V̂, Ŵ row-normalized.

    Inputs : v f32[N,M], wf f32[N,M], x f32[N,d], tau f32[], norm f32[]
    Outputs: loss f32[], grad_v f32[N,M], grad_w f32[N,M],
             sort_idx i32[N], colsum f32[N]
    """
    assert n == h * w_grid

    def step(v, wf, x, tau, norm):
        def loss_fn(params):
            v_, w_ = params
            vn = v_ / (jnp.linalg.norm(v_, axis=1, keepdims=True) + 1e-8)
            wn = w_ / (jnp.linalg.norm(w_, axis=1, keepdims=True) + 1e-8)
            p = jax.nn.softmax(scale * (vn @ wn.T) / tau, axis=1)
            y = p @ x
            y_grid = y.reshape(h, w_grid, d)
            colsum = jnp.sum(p, axis=0)
            loss = losses.combined(y_grid, colsum, x, y, norm)
            return loss, (p, colsum)

        (loss, (p, colsum)), grads = jax.value_and_grad(
            loss_fn, has_aux=True)((v, wf))
        sort_idx = jnp.argmax(p, axis=1).astype(jnp.int32)
        return loss, grads[0], grads[1], sort_idx, colsum

    return step


# --------------------------------------------------------------------------
# Eval-only forward (used by Rust for hardening/monitoring sweeps).
# --------------------------------------------------------------------------

def make_sss_eval(n: int, d: int, block: int = 32):
    """Forward-only fused apply: (y, sort_idx, colsum)."""

    def ev(w, x, tau):
        return softsort_apply_pallas(w, x, tau, block=block)

    return ev
