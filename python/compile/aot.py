"""AOT lowering: every ArtifactSpec → artifacts/<name>.hlo.txt + manifest.

Interchange is **HLO text**, not serialized HloModuleProto: jax ≥ 0.5 emits
protos with 64-bit instruction ids that xla_extension 0.5.1 (the version the
published ``xla`` 0.1.6 crate links) rejects (``proto.id() <= INT_MAX``).
The HLO text parser reassigns ids, so text round-trips cleanly.
(See /opt/xla-example/README.md.)

Run via ``make artifacts``:  ``cd python && python -m compile.aot --out ../artifacts``
Python never runs on the Rust request path.
"""

from __future__ import annotations

import argparse
import json
import os
import sys
import time

import jax
import jax.numpy as jnp
from jax._src.lib import xla_client as xc

from . import model
from .shapes import ARTIFACTS, ArtifactSpec

F32 = jnp.float32
I32 = jnp.int32


def to_hlo_text(lowered) -> str:
    """StableHLO → XlaComputation → HLO text (the 0.5.1-safe interchange)."""
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def _io_entry(name, dtype, shape):
    return {"name": name, "dtype": dtype, "shape": list(shape)}


def build_spec(spec: ArtifactSpec):
    """Return (jitted_fn, example_args, input_descs, output_descs)."""
    n, d, h, w, m = spec.n, spec.d, spec.h, spec.w, spec.m
    sds = jax.ShapeDtypeStruct

    if spec.method == "sss":
        fn = model.make_sss_step(n, d, h, w, block=spec.block)
        args = (sds((n,), F32), sds((n, d), F32), sds((n,), I32),
                sds((), F32), sds((), F32))
        ins = [_io_entry("w", "f32", (n,)), _io_entry("x_shuf", "f32", (n, d)),
               _io_entry("inv_idx", "i32", (n,)), _io_entry("tau", "f32", ()),
               _io_entry("norm", "f32", ())]
        outs = [_io_entry("loss", "f32", ()), _io_entry("grad", "f32", (n,)),
                _io_entry("sort_idx", "i32", (n,)),
                _io_entry("colsum", "f32", (n,)), _io_entry("y", "f32", (n, d))]
    elif spec.method == "gs":
        fn = model.make_gs_step(n, d, h, w)
        args = (sds((n, n), F32), sds((n, d), F32), sds((n, n), F32),
                sds((), F32), sds((), F32))
        ins = [_io_entry("logits", "f32", (n, n)), _io_entry("x", "f32", (n, d)),
               _io_entry("gumbel", "f32", (n, n)), _io_entry("tau", "f32", ()),
               _io_entry("norm", "f32", ())]
        outs = [_io_entry("loss", "f32", ()), _io_entry("grad", "f32", (n, n)),
                _io_entry("sort_idx", "i32", (n,)),
                _io_entry("colsum", "f32", (n,))]
    elif spec.method == "gs_probe":
        fn = model.make_gs_probe(n)
        args = (sds((n, n), F32), sds((n, n), F32), sds((), F32))
        ins = [_io_entry("logits", "f32", (n, n)),
               _io_entry("gumbel", "f32", (n, n)), _io_entry("tau", "f32", ())]
        outs = [_io_entry("p", "f32", (n, n))]
    elif spec.method == "kiss":
        fn = model.make_kiss_step(n, m, d, h, w)
        args = (sds((n, m), F32), sds((n, m), F32), sds((n, d), F32),
                sds((), F32), sds((), F32))
        ins = [_io_entry("v", "f32", (n, m)), _io_entry("w", "f32", (n, m)),
               _io_entry("x", "f32", (n, d)), _io_entry("tau", "f32", ()),
               _io_entry("norm", "f32", ())]
        outs = [_io_entry("loss", "f32", ()),
                _io_entry("grad_v", "f32", (n, m)),
                _io_entry("grad_w", "f32", (n, m)),
                _io_entry("sort_idx", "i32", (n,)),
                _io_entry("colsum", "f32", (n,))]
    else:
        raise ValueError(spec.method)
    return jax.jit(fn), args, ins, outs


def lower_one(spec: ArtifactSpec, out_dir: str) -> dict:
    fn, args, ins, outs = build_spec(spec)
    t0 = time.time()
    text = to_hlo_text(fn.lower(*args))
    path = f"{spec.name}.hlo.txt"
    with open(os.path.join(out_dir, path), "w") as f:
        f.write(text)
    dt = time.time() - t0
    print(f"  {spec.name:34s} {len(text)/1e6:7.2f} MB text  {dt:6.1f}s",
          flush=True)
    return {
        "name": spec.name, "method": spec.method, "file": path,
        "n": spec.n, "d": spec.d, "h": spec.h, "w": spec.w, "m": spec.m,
        "block": spec.block, "param_count": spec.param_count,
        "inputs": ins, "outputs": outs,
    }


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--out", default="../artifacts")
    ap.add_argument("--only", default=None,
                    help="comma-separated artifact-name substrings to build")
    args = ap.parse_args()
    os.makedirs(args.out, exist_ok=True)

    specs = ARTIFACTS
    if args.only:
        keys = args.only.split(",")
        specs = [s for s in specs if any(k in s.name for k in keys)]

    print(f"lowering {len(specs)} artifacts -> {args.out}", flush=True)
    entries = []
    for spec in specs:
        entries.append(lower_one(spec, args.out))

    manifest = {
        "version": 1,
        "jax_version": jax.__version__,
        "interchange": "hlo-text",
        "artifacts": entries,
    }
    with open(os.path.join(args.out, "manifest.json"), "w") as f:
        json.dump(manifest, f, indent=1)
    print(f"wrote manifest with {len(entries)} entries")


if __name__ == "__main__":
    sys.exit(main())
