"""Differentiable gather/sort primitives with explicit VJPs.

The image's jax install is a patched hybrid: ``GatherDimensionNumbers``
lacks ``operand_batching_dims`` while the gather transpose rule passes it,
so *any* reverse-mode gradient through gather/take/sort raises ``TypeError``.
Every gather that appears on a differentiated path must therefore go through
the ``custom_vjp`` wrappers below, whose backward passes are scatter-adds
(scatter construction is unaffected by the bug).

Forward-only gathers (argmax extraction, shuffling done outside the grad
path) may use plain indexing.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np


def float0_zeros(shape):
    """Zero cotangent for integer-dtype primal arguments."""
    return np.zeros(shape, dtype=jax.dtypes.float0)


# --------------------------------------------------------------------------
# take0: x[idx] along axis 0, differentiable w.r.t. x.
# --------------------------------------------------------------------------

@jax.custom_vjp
def take0(x, idx):
    """Gather rows of ``x`` (any trailing shape) at ``idx`` (1-D int array)."""
    return x[idx]


def _take0_fwd(x, idx):
    return x[idx], (idx, x.shape)


def _take0_bwd(res, ct):
    idx, shape = res
    gx = jnp.zeros(shape, ct.dtype).at[idx].add(ct)
    return gx, float0_zeros(idx.shape)


take0.defvjp(_take0_fwd, _take0_bwd)


# --------------------------------------------------------------------------
# sort_desc: descending sort, differentiable (gradient is the inverse
# permutation scatter — sort is differentiable a.e.).
# --------------------------------------------------------------------------

@jax.custom_vjp
def sort_desc(w):
    """Sort a 1-D vector in descending order."""
    return -jnp.sort(-w)


def _sort_desc_fwd(w):
    idx = jnp.argsort(-w)
    return w[idx], (idx, w.shape)


def _sort_desc_bwd(res, ct):
    idx, shape = res
    gw = jnp.zeros(shape, ct.dtype).at[idx].add(ct)
    return (gw,)


sort_desc.defvjp(_sort_desc_fwd, _sort_desc_bwd)
