"""Layer-2 loss functions (paper eq. 2–4).

    L(P) = L_nbr(P) + λ_s · L_s(P) + λ_σ · L_σ(P),   λ_s = 1, λ_σ = 2

* ``l_nbr``  — smoothness term: normalized mean L2 distance of horizontally
  and vertically adjacent grid cells of the (reverse-shuffled) soft output.
  Separable — needs only y, never the N×N matrix.
* ``l_s``    — stochastic-constraint loss (eq. 3) on the column sums of P
  (the row sums are exactly 1 by softmax construction).
* ``l_sigma``— std-preservation loss (eq. 4): |σ_X − σ_Y| / σ_X over all
  N·d entries; pushes P away from the uniform-averaging fixed point.

The normalizer ``norm`` (dataset mean pairwise distance) is computed once by
the Rust coordinator and passed as a scalar input, keeping the artifact free
of any O(N²) work.
"""

from __future__ import annotations

import jax.numpy as jnp

LAMBDA_S = 1.0
LAMBDA_SIGMA = 2.0
EPS = 1e-12


def l_nbr(y_grid, norm, metric: str = "l2"):
    """Normalized mean neighbor distance on an (H, W, d) grid.

    Mean of d(y[h,w], y[h,w+1]) over horizontal pairs and vertical
    analogues, divided by ``norm``. ``metric`` selects L2 (per-pair
    Euclidean) or L1 (mean absolute channel difference — [2]'s "color
    distance" formulation, gradient magnitude independent of the gap).
    Works for H == 1 (pure 1-D chains, Fig. 3) — the vertical term vanishes.
    """
    h, w, _ = y_grid.shape

    def pair_dist(diff):
        if metric == "l1":
            return jnp.sum(jnp.abs(diff), axis=-1)
        return jnp.sqrt(jnp.sum(diff * diff, axis=-1) + EPS)

    horiz = pair_dist(y_grid[:, 1:, :] - y_grid[:, :-1, :])
    total = jnp.sum(horiz)
    count = h * (w - 1)
    if h > 1:
        vert = pair_dist(y_grid[1:, :, :] - y_grid[:-1, :, :])
        total = total + jnp.sum(vert)
        count += (h - 1) * w
    return total / (count * norm)


def l_s(colsum):
    """Stochastic-constraint loss (eq. 3): mean squared column-sum error."""
    dev = colsum - 1.0
    return jnp.mean(dev * dev)


def l_sigma(x, y):
    """Std-preservation loss (eq. 4) over all entries."""
    sx = jnp.std(x)
    sy = jnp.std(y)
    return jnp.abs(sx - sy) / (sx + EPS)


def combined(y_grid, colsum, x, y, norm,
             lambda_s: float = LAMBDA_S, lambda_sigma: float = LAMBDA_SIGMA):
    """Full eq. (2) objective."""
    return (l_nbr(y_grid, norm)
            + lambda_s * l_s(colsum)
            + lambda_sigma * l_sigma(x, y))
