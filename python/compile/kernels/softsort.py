"""Layer-1 Pallas kernel: fused SoftSort-apply.

For weights ``w ∈ R^N``, data ``x ∈ R^{N×d}`` and temperature ``τ`` the
SoftSort relaxation (Prillo & Eisenschlos, ICML 2020; eq. 1 of the paper) is

    P = softmax_rows( -|sort_desc(w)_i - w_j| / τ )          (N×N)

and the quantities the training step actually needs are

    y        = P @ x                 soft-sorted data          (N×d)
    sort_idx = argmax_rows(P)        hard permutation draft    (N,)
    colsum   = Σ_i P_ij              for the L_s loss (eq. 3)  (N,)

The kernel computes all three in ONE pass over a row-block grid without ever
materializing the N×N matrix in HBM — the paper's "row-wise" memory
requirement (§II) expressed as a BlockSpec schedule:

  grid step i (of N/B):
    VMEM in : ws block (B,), full w (N,), full x (N,d), τ (1,1)
    compute : B×N block of P (block-local softmax — each block spans a full
              row, so row max/sum need no cross-step state)
    VMEM out: y tile (B,d), idx tile (B,), colsum accumulator (N,) shared
              across steps (same output block every step).

VMEM footprint ≈ 4·(B·N + N·d + B·d + 2N) bytes; with B=32 every shipped
shape fits a 16 MB TPU VMEM budget (see DESIGN.md §Hardware-Adaptation).

``interpret=True`` always: the CPU PJRT plugin cannot execute Mosaic
custom-calls; interpret mode lowers the kernel to plain HLO so the same
artifact runs under the Rust runtime. Correctness vs the dense oracle in
``kernels/ref.py`` is enforced by ``python/tests/test_kernel.py``.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from ..primitives import sort_desc

DEFAULT_BLOCK = 32


def _softsort_kernel(tau_ref, ws_ref, w_ref, x_ref, y_ref, idx_ref, cs_ref):
    """One row-block of the fused SoftSort-apply (see module docstring)."""
    i = pl.program_id(0)
    tau = tau_ref[0, 0]
    ws = ws_ref[...]                       # (B,)  sorted-descending block
    w = w_ref[...]                         # (N,)  full weight vector

    # B×N block of logits; one-pass block-local softmax (rows are complete).
    logits = -jnp.abs(ws[:, None] - w[None, :]) / tau
    m = jnp.max(logits, axis=1, keepdims=True)
    p = jnp.exp(logits - m)
    denom = jnp.sum(p, axis=1, keepdims=True)
    prob = p / denom                       # (B,N) block of P

    y_ref[...] = jnp.dot(prob, x_ref[...].astype(prob.dtype)).astype(y_ref.dtype)
    idx_ref[...] = jnp.argmax(prob, axis=1).astype(jnp.int32)

    # Column-sum accumulator: every grid step maps to the same output block.
    @pl.when(i == 0)
    def _init():
        cs_ref[...] = jnp.zeros_like(cs_ref)

    cs_ref[...] += jnp.sum(prob, axis=0).astype(cs_ref.dtype)


def pick_block(n: int, requested: int = DEFAULT_BLOCK) -> int:
    """Largest block size ≤ requested that divides n."""
    b = min(requested, n)
    while n % b != 0:
        b -= 1
    return b


@functools.partial(jax.jit, static_argnames=("block",))
def softsort_apply_pallas(w, x, tau, block: int = DEFAULT_BLOCK):
    """Fused SoftSort-apply via the Pallas row-block kernel.

    Args:
      w:   f32[N] trainable weights.
      x:   [N, d] data to be soft-sorted (f32 or bf16).
      tau: f32[] temperature.
      block: row-block size (static); must divide N after clamping.

    Returns:
      (y [N,d], sort_idx i32[N], colsum f32[N]).
    """
    n, d = x.shape
    b = pick_block(n, block)
    ws = sort_desc(w)
    tau2 = jnp.reshape(tau, (1, 1)).astype(jnp.float32)
    return pl.pallas_call(
        _softsort_kernel,
        grid=(n // b,),
        in_specs=[
            pl.BlockSpec((1, 1), lambda i: (0, 0)),    # tau
            pl.BlockSpec((b,), lambda i: (i,)),        # ws block
            pl.BlockSpec((n,), lambda i: (0,)),        # full w
            pl.BlockSpec((n, d), lambda i: (0, 0)),    # full x
        ],
        out_specs=[
            pl.BlockSpec((b, d), lambda i: (i, 0)),    # y tile
            pl.BlockSpec((b,), lambda i: (i,)),        # idx tile
            pl.BlockSpec((n,), lambda i: (0,)),        # colsum accumulator
        ],
        out_shape=[
            jax.ShapeDtypeStruct((n, d), x.dtype),
            jax.ShapeDtypeStruct((n,), jnp.int32),
            jax.ShapeDtypeStruct((n,), jnp.float32),
        ],
        interpret=True,   # CPU PJRT cannot run Mosaic custom-calls
    )(tau2, ws, w, x)


def vmem_bytes(n: int, d: int, block: int = DEFAULT_BLOCK) -> int:
    """Estimated VMEM working set of one grid step (f32), for DESIGN §Perf."""
    b = pick_block(n, block)
    return 4 * (b * n + n * d + b * d + 2 * n + b + 1)
