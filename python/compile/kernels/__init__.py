# L1: Pallas kernel(s) + oracles for the paper's compute hot-spot.
from .softsort import softsort_apply_pallas, pick_block, vmem_bytes  # noqa: F401
from .ref import (  # noqa: F401
    softsort_matrix,
    softsort_apply_ref,
    softsort_apply_chunked,
)
