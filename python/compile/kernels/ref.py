"""Pure-jnp oracles for the Pallas SoftSort kernel.

Two references:

* ``softsort_apply_ref`` — dense N×N, the ground truth for pytest.
* ``softsort_apply_chunked`` — O(C·N) memory row-chunked evaluation used as
  the *backward* pass of the custom_vjp in ``model.py`` (with
  ``jax.checkpoint`` so reverse-mode never stores the N×N matrix).

Both must agree with the kernel to float tolerance; enforced by
``python/tests/test_kernel.py``.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from ..primitives import sort_desc


def softsort_matrix(w, tau):
    """Dense SoftSort relaxation P (eq. 1): row-softmax of -|ws_i - w_j|/τ."""
    ws = sort_desc(w)
    logits = -jnp.abs(ws[:, None] - w[None, :]) / tau
    return jax.nn.softmax(logits, axis=1)


def softsort_apply_ref(w, x, tau):
    """Dense reference of the fused kernel: (y, sort_idx, colsum)."""
    prob = softsort_matrix(w, tau)
    y = (prob @ x.astype(prob.dtype)).astype(x.dtype)
    sort_idx = jnp.argmax(prob, axis=1).astype(jnp.int32)
    colsum = jnp.sum(prob, axis=0).astype(jnp.float32)
    return y, sort_idx, colsum


def _chunk_body(ws_blk, w, x, tau):
    """(y, colsum contribution) for one row chunk of P."""
    logits = -jnp.abs(ws_blk[:, None] - w[None, :]) / tau
    prob = jax.nn.softmax(logits, axis=1)
    return prob @ x.astype(prob.dtype), jnp.sum(prob, axis=0)


@functools.partial(jax.jit, static_argnames=("chunk",))
def softsort_apply_chunked(w, x, tau, chunk: int = 128):
    """Row-chunked (y, colsum); peak live memory O(chunk·N), grad-safe.

    ``jax.checkpoint`` on the chunk body makes reverse-mode recompute the
    chunk's P block instead of storing it, so even under ``jax.grad`` the
    N×N matrix never exists — the paper's §II memory requirement holds for
    the backward pass too.
    """
    n, d = x.shape
    c = min(chunk, n)
    while n % c != 0:
        c -= 1
    ws = sort_desc(w)
    body = jax.checkpoint(functools.partial(_chunk_body, w=w, x=x, tau=tau))
    ys, css = jax.lax.map(body, ws.reshape(n // c, c))
    y = ys.reshape(n, d).astype(x.dtype)
    colsum = jnp.sum(css, axis=0).astype(jnp.float32)
    return y, colsum
