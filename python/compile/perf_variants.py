"""Perf-pass helper: lower sss_step variants with different Pallas row-block
sizes and backward chunk sizes so the Rust side can measure per-step wall
time and pick the production configuration (EXPERIMENTS.md §Perf).

Usage: cd python && python -m compile.perf_variants --out ../artifacts_perf
"""

from __future__ import annotations

import argparse
import json
import os

import jax

from . import model
from .aot import _io_entry, to_hlo_text
from .shapes import ArtifactSpec

F32 = "f32"


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--out", default="../artifacts_perf")
    ap.add_argument("--n", type=int, default=1024)
    ap.add_argument("--d", type=int, default=3)
    args = ap.parse_args()
    os.makedirs(args.out, exist_ok=True)
    n, d = args.n, args.d
    h = int(n ** 0.5)

    entries = []
    for block in [16, 32, 64, 128, 256]:
        for chunk in [64, 128, 256]:
            # chunk is baked into softsort_apply's bwd via default; rebuild
            # model fn with a patched chunk by closing over it.
            import functools

            from .kernels.ref import softsort_apply_chunked
            from .kernels.softsort import softsort_apply_pallas

            @functools.partial(jax.custom_vjp, nondiff_argnums=(3,))
            def ssa(w, x, tau, blk=block):
                return softsort_apply_pallas(w, x, tau, block=blk)

            def _fwd(w, x, tau, blk=block):
                return softsort_apply_pallas(w, x, tau, block=blk), (w, x, tau)

            def _bwd(blk, res, ct, _chunk=chunk):
                w, x, tau = res
                ct_y, _ct_idx, ct_cs = ct

                def f(w_, x_):
                    return softsort_apply_chunked(w_, x_, tau, chunk=_chunk)

                _, vjp = jax.vjp(f, w, x)
                gw, gx = vjp((ct_y.astype(x.dtype), ct_cs))
                import jax.numpy as jnp

                return gw, gx, jnp.zeros((), dtype=tau.dtype)

            ssa.defvjp(_fwd, _bwd)

            orig = model.softsort_apply
            model.softsort_apply = ssa
            try:
                fn = jax.jit(model.make_sss_step(n, d, h, n // h, block=block))
            finally:
                model.softsort_apply = orig

            import jax.numpy as jnp

            sds = jax.ShapeDtypeStruct
            lowered = fn.lower(
                sds((n,), jnp.float32), sds((n, d), jnp.float32),
                sds((n,), jnp.int32), sds((), jnp.float32), sds((), jnp.float32),
            )
            name = f"sss_step_b{block}_c{chunk}_n{n}_d{d}"
            with open(os.path.join(args.out, f"{name}.hlo.txt"), "w") as f:
                f.write(to_hlo_text(lowered))
            spec = ArtifactSpec("sss", n, d, h, n // h, block=block)
            entries.append({
                "name": name, "method": "sss", "file": f"{name}.hlo.txt",
                "n": n, "d": d, "h": h, "w": n // h, "m": 0, "block": block,
                "param_count": n,
                "inputs": [_io_entry("w", F32, (n,)), _io_entry("x_shuf", F32, (n, d)),
                           _io_entry("inv_idx", "i32", (n,)), _io_entry("tau", F32, ()),
                           _io_entry("norm", F32, ())],
                "outputs": [_io_entry("loss", F32, ()), _io_entry("grad", F32, (n,)),
                            _io_entry("sort_idx", "i32", (n,)),
                            _io_entry("colsum", F32, (n,)), _io_entry("y", F32, (n, d))],
            })
            print(f"  {name}", flush=True)

    with open(os.path.join(args.out, "manifest.json"), "w") as f:
        json.dump({"version": 1, "jax_version": jax.__version__,
                   "interchange": "hlo-text", "artifacts": entries}, f, indent=1)
    print(f"wrote {len(entries)} perf variants -> {args.out}")


if __name__ == "__main__":
    main()
