"""Loss components (eq. 2–4) against hand-computed values."""

import jax.numpy as jnp
import numpy as np
import pytest

from compile import losses


def test_l_nbr_constant_grid_is_zero():
    y = jnp.ones((4, 4, 3)) * 0.7
    assert float(losses.l_nbr(y, jnp.float32(1.0))) < 1e-5


def test_l_nbr_hand_computed_1d():
    # chain [0, 1, 3]: neighbor distances 1 and 2 → mean 1.5; norm=0.5 → 3.0
    y = jnp.array([[[0.0], [1.0], [3.0]]])
    got = float(losses.l_nbr(y, jnp.float32(0.5)))
    assert got == pytest.approx(3.0, abs=1e-4)


def test_l_nbr_hand_computed_2d():
    # 2x2 grid, scalar features [[0,1],[2,4]]:
    # horiz: |0-1|=1, |2-4|=2 ; vert: |0-2|=2, |1-4|=3 ; mean = 8/4 = 2
    y = jnp.array([[[0.0], [1.0]], [[2.0], [4.0]]])
    got = float(losses.l_nbr(y, jnp.float32(1.0)))
    assert got == pytest.approx(2.0, abs=1e-4)


def test_l_nbr_uses_l2_over_feature_dim():
    # single horizontal pair with diff (3,4) → distance 5
    y = jnp.array([[[0.0, 0.0], [3.0, 4.0]]])
    got = float(losses.l_nbr(y, jnp.float32(1.0)))
    assert got == pytest.approx(5.0, abs=1e-4)


def test_l_s_perfect_and_off():
    assert float(losses.l_s(jnp.ones(10))) == pytest.approx(0.0, abs=1e-8)
    # colsum [2,0]: ((1)^2 + (-1)^2)/2 = 1
    assert float(losses.l_s(jnp.array([2.0, 0.0]))) == pytest.approx(1.0, abs=1e-6)


def test_l_sigma_zero_for_same_std():
    x = jnp.array([[0.0], [1.0], [2.0]])
    assert float(losses.l_sigma(x, x + 5.0)) == pytest.approx(0.0, abs=1e-6)


def test_l_sigma_collapse_penalized():
    rng = np.random.default_rng(0)
    x = jnp.asarray(rng.normal(size=(32, 4)), jnp.float32)
    y = jnp.zeros_like(x)  # fully averaged output
    assert float(losses.l_sigma(x, y)) == pytest.approx(1.0, abs=1e-5)


def test_combined_weights():
    x = jnp.asarray(np.random.default_rng(1).normal(size=(16, 2)), jnp.float32)
    y = x * 0.5
    yg = y.reshape(4, 4, 2)
    cs = jnp.full(16, 1.25)
    norm = jnp.float32(2.0)
    expect = (float(losses.l_nbr(yg, norm))
              + 1.0 * float(losses.l_s(cs))
              + 2.0 * float(losses.l_sigma(x, y)))
    assert float(losses.combined(yg, cs, x, y, norm)) == pytest.approx(expect, rel=1e-5)
