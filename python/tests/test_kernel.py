"""L1 correctness: Pallas fused SoftSort-apply vs the dense jnp oracle.

Hypothesis sweeps shapes, temperatures, block sizes and dtypes — the CORE
correctness signal for the kernel that every artifact embeds.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels.ref import (
    softsort_apply_chunked,
    softsort_apply_ref,
    softsort_matrix,
)
from compile.kernels.softsort import pick_block, softsort_apply_pallas, vmem_bytes

SETTINGS = dict(max_examples=20, deadline=None)


def _rand(n, d, seed, scale=3.0):
    rng = np.random.default_rng(seed)
    w = jnp.asarray(rng.normal(size=(n,)) * scale, jnp.float32)
    x = jnp.asarray(rng.uniform(size=(n, d)), jnp.float32)
    return w, x


@settings(**SETTINGS)
@given(
    n=st.sampled_from([8, 16, 24, 32, 48, 64, 96]),
    d=st.integers(1, 8),
    tau=st.sampled_from([0.05, 0.2, 1.0, 4.0]),
    block=st.sampled_from([8, 16, 32]),
    seed=st.integers(0, 10_000),
)
def test_kernel_matches_dense_ref(n, d, tau, block, seed):
    w, x = _rand(n, d, seed)
    t = jnp.float32(tau)
    y1, i1, c1 = softsort_apply_pallas(w, x, t, block=block)
    y2, i2, c2 = softsort_apply_ref(w, x, t)
    np.testing.assert_allclose(np.asarray(y1), np.asarray(y2), rtol=1e-5, atol=1e-5)
    np.testing.assert_array_equal(np.asarray(i1), np.asarray(i2))
    np.testing.assert_allclose(np.asarray(c1), np.asarray(c2), rtol=1e-4, atol=1e-4)


@settings(**SETTINGS)
@given(
    n=st.sampled_from([16, 64, 128]),
    d=st.integers(1, 6),
    tau=st.sampled_from([0.1, 0.7, 2.0]),
    chunk=st.sampled_from([8, 32, 128]),
    seed=st.integers(0, 10_000),
)
def test_chunked_matches_dense_ref(n, d, tau, chunk, seed):
    w, x = _rand(n, d, seed)
    t = jnp.float32(tau)
    y1, c1 = softsort_apply_chunked(w, x, t, chunk=chunk)
    y2, _, c2 = softsort_apply_ref(w, x, t)
    np.testing.assert_allclose(np.asarray(y1), np.asarray(y2), rtol=1e-5, atol=1e-5)
    np.testing.assert_allclose(np.asarray(c1), np.asarray(c2), rtol=1e-4, atol=1e-4)


def test_kernel_bf16_tolerance():
    w, x = _rand(64, 4, 7)
    xb = x.astype(jnp.bfloat16)
    y1, i1, c1 = softsort_apply_pallas(w, xb, jnp.float32(0.5))
    y2, i2, c2 = softsort_apply_ref(w, x, jnp.float32(0.5))
    assert y1.dtype == jnp.bfloat16
    np.testing.assert_allclose(np.asarray(y1, np.float32), np.asarray(y2),
                               rtol=3e-2, atol=3e-2)
    np.testing.assert_array_equal(np.asarray(i1), np.asarray(i2))


def test_low_tau_is_hard_permutation():
    """τ → 0: P must converge to the exact argsort permutation matrix."""
    rng = np.random.default_rng(3)
    w = jnp.asarray(rng.permutation(32).astype(np.float32))
    x = jnp.asarray(rng.uniform(size=(32, 3)), jnp.float32)
    y, idx, cs = softsort_apply_pallas(w, x, jnp.float32(0.01))
    expect = np.argsort(-np.asarray(w), kind="stable")
    np.testing.assert_array_equal(np.asarray(idx), expect)
    np.testing.assert_allclose(np.asarray(cs), np.ones(32), atol=1e-3)
    np.testing.assert_allclose(np.asarray(y), np.asarray(x)[expect], atol=1e-3)


def test_rows_sum_to_one():
    w, x = _rand(48, 2, 11)
    p = softsort_matrix(w, jnp.float32(0.8))
    np.testing.assert_allclose(np.asarray(p.sum(axis=1)), np.ones(48), atol=1e-5)
    np.testing.assert_allclose(float(p.sum()), 48.0, rtol=1e-5)


def test_linear_init_conventions():
    """Order-preserving init (Algorithm 1: "initially preserves the previous
    order") is the DESCENDING ramp under eq. (1)'s descending-sort convention;
    the ascending ramp reverses. The Rust coordinator inits descending."""
    n = 40
    x = jnp.asarray(np.random.default_rng(5).uniform(size=(n, 3)), jnp.float32)
    asc = jnp.arange(n, dtype=jnp.float32)
    _, idx_asc, _ = softsort_apply_pallas(asc, x, jnp.float32(0.05))
    np.testing.assert_array_equal(np.asarray(idx_asc), np.arange(n)[::-1])
    desc = jnp.arange(n, 0, -1, dtype=jnp.float32)
    _, idx_desc, _ = softsort_apply_pallas(desc, x, jnp.float32(0.05))
    np.testing.assert_array_equal(np.asarray(idx_desc), np.arange(n))


def test_pick_block():
    assert pick_block(64, 32) == 32
    assert pick_block(16, 32) == 16
    assert pick_block(48, 32) == 24
    assert pick_block(7, 32) == 7
    for n in [8, 12, 100, 1024]:
        assert n % pick_block(n, 32) == 0


def test_vmem_budget_for_shipped_shapes():
    """Every shipped artifact shape must fit a 16 MB VMEM budget (DESIGN §9)."""
    from compile.shapes import ARTIFACTS
    for s in ARTIFACTS:
        if s.method == "sss":
            assert vmem_bytes(s.n, s.d, s.block) <= 16 * 2**20, s.name
