"""Step functions: gradient correctness (finite differences), backward-path
memory discipline, and a short end-to-end optimization sanity run per method.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from compile import losses, model
from compile.kernels.ref import softsort_apply_ref
from compile.primitives import take0

N, D, H, W = 16, 3, 4, 4


def _data(seed=0):
    rng = np.random.default_rng(seed)
    x = jnp.asarray(rng.uniform(size=(N, D)), jnp.float32)
    w = jnp.asarray(rng.normal(size=(N,)).astype(np.float32) * 2)
    inv = jnp.asarray(rng.permutation(N).astype(np.int32))
    return w, x, inv


def _dense_loss(w, x, inv, tau, norm):
    """Same objective as make_sss_step but via the dense oracle only."""
    y, _, cs = softsort_apply_ref(w, x, tau)
    yg = take0(y, inv).reshape(H, W, D)
    return losses.combined(yg, cs, x, y, norm)


def test_sss_step_loss_matches_dense():
    w, x, inv = _data()
    tau, norm = jnp.float32(0.7), jnp.float32(0.4)
    step = jax.jit(model.make_sss_step(N, D, H, W, block=8))
    loss, grad, idx, cs, y = step(w, x, inv, tau, norm)
    expect = _dense_loss(w, x, inv, tau, norm)
    assert float(loss) == pytest.approx(float(expect), rel=1e-4)


def test_sss_step_grad_matches_dense_autodiff():
    w, x, inv = _data(2)
    tau, norm = jnp.float32(0.5), jnp.float32(0.4)
    step = jax.jit(model.make_sss_step(N, D, H, W, block=8))
    _, grad, *_ = step(w, x, inv, tau, norm)
    gref = jax.grad(lambda w_: _dense_loss(w_, x, inv, tau, norm))(w)
    np.testing.assert_allclose(np.asarray(grad), np.asarray(gref),
                               rtol=1e-3, atol=1e-4)


def test_sss_step_grad_matches_finite_differences():
    w, x, inv = _data(3)
    tau, norm = jnp.float32(1.0), jnp.float32(0.4)
    step = jax.jit(model.make_sss_step(N, D, H, W, block=8))
    _, grad, *_ = step(w, x, inv, tau, norm)
    eps = 1e-2
    wn = np.asarray(w, np.float64)
    # The objective is piecewise-smooth in w (kinks where the argsort order
    # flips); only probe coordinates whose ±eps ball stays on one piece.
    gaps = np.abs(wn[:, None] - wn[None, :]) + np.eye(N) * 1e9
    smooth = [i for i in range(N) if gaps[i].min() > 4 * eps]
    assert len(smooth) >= 4
    for i in smooth[:6]:
        wp, wm = wn.copy(), wn.copy()
        wp[i] += eps; wm[i] -= eps
        lp = float(step(jnp.asarray(wp, jnp.float32), x, inv, tau, norm)[0])
        lm = float(step(jnp.asarray(wm, jnp.float32), x, inv, tau, norm)[0])
        fd = (lp - lm) / (2 * eps)
        assert float(grad[i]) == pytest.approx(fd, rel=0.1, abs=2e-3)


def test_sss_optimization_reduces_loss_and_hardens():
    """A few Adam-free GD steps must reduce loss; τ→0 must yield a valid perm."""
    rng = np.random.default_rng(4)
    x = jnp.asarray(rng.uniform(size=(N, D)), jnp.float32)
    inv = jnp.arange(N, dtype=jnp.int32)
    norm = jnp.float32(np.sqrt(D / 6.0))
    step = jax.jit(model.make_sss_step(N, D, H, W, block=8))
    w = jnp.arange(N, 0, -1, dtype=jnp.float32)  # order-preserving init
    first = None
    for it in range(30):
        tau = jnp.float32(1.0 * (0.1 ** (it / 29)))
        loss, grad, idx, cs, y = step(w, x, inv, tau, norm)
        if first is None:
            first = float(loss)
        w = w - 5.0 * grad
    assert float(loss) < first
    # Hard extraction at the final low temperature:
    _, _, idx, _, _ = step(w, x, inv, jnp.float32(0.02), norm)
    assert sorted(np.asarray(idx).tolist()) == list(range(N))


def test_gs_step_grad_finite_differences():
    rng = np.random.default_rng(5)
    n, d, h, wg = 9, 2, 3, 3
    step = jax.jit(model.make_gs_step(n, d, h, wg))
    logits = jnp.asarray(rng.normal(size=(n, n)).astype(np.float32))
    x = jnp.asarray(rng.uniform(size=(n, d)), jnp.float32)
    gum = jnp.zeros((n, n), jnp.float32)
    tau, norm = jnp.float32(0.8), jnp.float32(0.5)
    loss, grad, idx, cs = step(logits, x, gum, tau, norm)
    eps = 1e-2
    ln = np.asarray(logits, np.float64)
    for (i, j) in [(0, 0), (4, 7), (8, 2)]:
        lp, lm = ln.copy(), ln.copy()
        lp[i, j] += eps; lm[i, j] -= eps
        fp = float(step(jnp.asarray(lp, jnp.float32), x, gum, tau, norm)[0])
        fm = float(step(jnp.asarray(lm, jnp.float32), x, gum, tau, norm)[0])
        fd = (fp - fm) / (2 * eps)
        assert float(grad[i, j]) == pytest.approx(fd, rel=0.1, abs=2e-3)


def test_gs_probe_doubly_stochastic():
    rng = np.random.default_rng(6)
    n = 16
    probe = jax.jit(model.make_gs_probe(n))
    logits = jnp.asarray(rng.normal(size=(n, n)).astype(np.float32) * 2)
    p = probe(logits, jnp.zeros((n, n), jnp.float32), jnp.float32(0.5))
    # 20 Sinkhorn sweeps: row sums exact (last normalization is per-column,
    # so allow a few % residual on the other axis).
    np.testing.assert_allclose(np.asarray(p.sum(0)), np.ones(n), atol=1e-4)
    np.testing.assert_allclose(np.asarray(p.sum(1)), np.ones(n), atol=5e-2)
    assert float(p.min()) >= 0.0


def test_kiss_step_grad_finite_differences():
    rng = np.random.default_rng(7)
    n, m, d, h, wg = 16, 5, 2, 4, 4
    step = jax.jit(model.make_kiss_step(n, m, d, h, wg))
    v = jnp.asarray(rng.normal(size=(n, m)).astype(np.float32))
    wf = jnp.asarray(rng.normal(size=(n, m)).astype(np.float32))
    x = jnp.asarray(rng.uniform(size=(n, d)), jnp.float32)
    tau, norm = jnp.float32(1.0), jnp.float32(0.5)
    loss, gv, gw, idx, cs = step(v, wf, x, tau, norm)
    eps = 1e-2
    vn = np.asarray(v, np.float64)
    for (i, j) in [(0, 0), (7, 3), (15, 4)]:
        vp, vm = vn.copy(), vn.copy()
        vp[i, j] += eps; vm[i, j] -= eps
        fp = float(step(jnp.asarray(vp, jnp.float32), wf, x, tau, norm)[0])
        fm = float(step(jnp.asarray(vm, jnp.float32), wf, x, tau, norm)[0])
        fd = (fp - fm) / (2 * eps)
        assert float(gv[i, j]) == pytest.approx(fd, rel=0.12, abs=3e-3)


def test_kiss_rows_normalized_invariance():
    """Scaling a row of V must not change the loss (row normalization)."""
    rng = np.random.default_rng(8)
    n, m, d, h, wg = 16, 5, 2, 4, 4
    step = jax.jit(model.make_kiss_step(n, m, d, h, wg))
    v = jnp.asarray(rng.normal(size=(n, m)).astype(np.float32))
    wf = jnp.asarray(rng.normal(size=(n, m)).astype(np.float32))
    x = jnp.asarray(rng.uniform(size=(n, d)), jnp.float32)
    l1 = float(step(v, wf, x, jnp.float32(1.0), jnp.float32(0.5))[0])
    v2 = v.at[3].multiply(7.0)
    l2 = float(step(v2, wf, x, jnp.float32(1.0), jnp.float32(0.5))[0])
    assert l1 == pytest.approx(l2, rel=1e-4)
