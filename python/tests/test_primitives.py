"""Grad correctness of the custom_vjp gather/sort workarounds.

The stock gather AD rule is broken in this jax build (primitives.py module
docstring); these tests pin the replacements to finite differences.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from compile.primitives import sort_desc, take0


def numerical_grad(f, x, eps=1e-3):
    g = np.zeros_like(np.asarray(x))
    flat = np.asarray(x, dtype=np.float64).ravel()
    for i in range(flat.size):
        xp = flat.copy(); xp[i] += eps
        xm = flat.copy(); xm[i] -= eps
        g.ravel()[i] = (f(jnp.asarray(xp.reshape(x.shape), jnp.float32))
                        - f(jnp.asarray(xm.reshape(x.shape), jnp.float32))) / (2 * eps)
    return g


def test_take0_forward():
    x = jnp.arange(12.0).reshape(4, 3)
    idx = jnp.array([2, 0, 3, 1])
    np.testing.assert_array_equal(np.asarray(take0(x, idx)), np.asarray(x)[np.asarray(idx)])


def test_take0_grad_matches_fd():
    rng = np.random.default_rng(0)
    x = jnp.asarray(rng.normal(size=(6, 2)), jnp.float32)
    idx = jnp.array([5, 3, 3, 0, 1, 2])  # duplicates exercise the scatter-ADD

    def f(x_):
        return jnp.sum(take0(x_, idx) ** 2 * jnp.arange(1.0, 13.0).reshape(6, 2))

    g = jax.grad(f)(x)
    gn = numerical_grad(f, x)
    np.testing.assert_allclose(np.asarray(g), gn, rtol=1e-2, atol=1e-3)


def test_sort_desc_forward():
    w = jnp.array([3.0, -1.0, 2.0, 7.0])
    np.testing.assert_array_equal(np.asarray(sort_desc(w)), [7.0, 3.0, 2.0, -1.0])


def test_sort_desc_grad_matches_fd():
    rng = np.random.default_rng(1)
    w = jnp.asarray(rng.normal(size=(8,)), jnp.float32)

    def f(w_):
        s = sort_desc(w_)
        return jnp.sum(s ** 3 * jnp.arange(1.0, 9.0))

    g = jax.grad(f)(w)
    gn = numerical_grad(f, w)
    np.testing.assert_allclose(np.asarray(g), gn, rtol=1e-2, atol=1e-2)


def test_sort_desc_grad_is_permuted_cotangent():
    w = jnp.array([0.5, 2.0, 1.0])
    # s = [2.0, 1.0, 0.5]; dL/ds = [1, 10, 100] → dL/dw = [100, 1, 10]
    g = jax.grad(lambda w_: jnp.sum(sort_desc(w_) * jnp.array([1.0, 10.0, 100.0])))(w)
    np.testing.assert_array_equal(np.asarray(g), [100.0, 1.0, 10.0])


def test_take0_jit_and_composition():
    x = jnp.arange(10.0).reshape(5, 2)
    idx = jnp.array([4, 3, 2, 1, 0])
    out = jax.jit(lambda x_, i: take0(take0(x_, i), i))(x, idx)
    np.testing.assert_array_equal(np.asarray(out), np.asarray(x))
