"""AOT layer: spec registry, HLO text emission, manifest schema."""

import json
import os

import jax
import jax.numpy as jnp
import pytest

from compile import aot
from compile.shapes import ARTIFACTS, ArtifactSpec, kissing_rank


def test_kissing_rank_matches_paper():
    # Table 2: Kissing memory 2*1024*M = 26624 → M = 13.
    assert kissing_rank(1024) == 13
    assert 2 * 1024 * kissing_rank(1024) == 26624
    assert kissing_rank(64) == 8
    assert kissing_rank(4096) == 16
    with pytest.raises(ValueError):
        kissing_rank(100_000)


def test_artifact_names_unique_and_grids_consistent():
    names = [s.name for s in ARTIFACTS]
    assert len(names) == len(set(names))
    for s in ARTIFACTS:
        if s.method in ("sss", "gs", "kiss"):
            assert s.n == s.h * s.w, s.name


def test_param_counts():
    by = {s.name: s for s in ARTIFACTS}
    assert by["sss_step_n1024_d3_h32"].param_count == 1024
    assert by["gs_step_n1024_d3_h32"].param_count == 1024 * 1024
    assert by["kiss_step_n1024_m13_d3"].param_count == 26624


def test_hlo_text_emission_smoke():
    spec = ArtifactSpec("sss", 16, 3, 4, 4, block=8)
    fn, args, ins, outs = aot.build_spec(spec)
    text = aot.to_hlo_text(fn.lower(*args))
    assert text.startswith("HloModule")
    assert "custom-call" not in text.lower(), \
        "interpret=True must lower pallas to plain HLO (no Mosaic custom-call)"
    assert len(ins) == 5 and len(outs) == 5


def test_built_manifest_schema():
    """If make artifacts ran, validate the manifest against the registry."""
    path = os.path.join(os.path.dirname(__file__), "..", "..",
                        "artifacts", "manifest.json")
    if not os.path.exists(path):
        pytest.skip("artifacts not built")
    with open(path) as f:
        man = json.load(f)
    assert man["interchange"] == "hlo-text"
    entries = {e["name"]: e for e in man["artifacts"]}
    for s in ARTIFACTS:
        assert s.name in entries, f"missing artifact {s.name}"
        e = entries[s.name]
        assert e["param_count"] == s.param_count
        hlo = os.path.join(os.path.dirname(path), e["file"])
        assert os.path.exists(hlo)
        with open(hlo) as fh:
            assert fh.read(9) == "HloModule"
        for io in e["inputs"] + e["outputs"]:
            assert io["dtype"] in ("f32", "i32")
