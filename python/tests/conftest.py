import os
import sys

# Tests import the compile package as `compile.*`; run from python/.
sys.path.insert(0, os.path.dirname(os.path.dirname(os.path.abspath(__file__))))
