//! §Perf harness: measure per-step execute time of the sss_step variants
//! lowered by `python -m compile.perf_variants` (Pallas row-block B ×
//! backward chunk C) and print the ranking. Drives the L1/L2 rows of
//! EXPERIMENTS.md §Perf. Samples land in the same machine-readable report
//! scheme as the bench targets (`target/bench_reports/perf_sweep.json`,
//! written through the `serve::json` serializer), so the CI perf artifact
//! format covers every bench in the repo.

use shufflesort::bench::{bench, write_json_report, Sample};
use shufflesort::runtime::{Arg, Runtime};

const REPORT_PATH: &str = "target/bench_reports/perf_sweep.json";

fn main() -> anyhow::Result<()> {
    let dir = std::env::args().nth(1).unwrap_or_else(|| "artifacts_perf".into());
    let rt = Runtime::from_manifest(&dir)?;
    let names = rt.artifact_names();
    println!("{} variants in {dir}", names.len());

    let mut samples: Vec<Sample> = Vec::new();
    for name in names {
        let exe = rt.load(&name)?;
        let n = exe.meta.n;
        let d = exe.meta.d;
        let w: Vec<f32> = (0..n).map(|i| (n - i) as f32).collect();
        let x: Vec<f32> = (0..n * d).map(|i| ((i * 2654435761) % 1000) as f32 / 1000.0).collect();
        let inv: Vec<i32> = (0..n as i32).collect();
        let s = bench(&name, 3, 15, || {
            exe.run(&[
                Arg::F32(&w),
                Arg::F32(&x),
                Arg::I32(&inv),
                Arg::ScalarF32(0.3),
                Arg::ScalarF32(0.5),
            ])
            .unwrap()
        });
        println!("{}", s.line());
        samples.push(s);
    }

    let mut ranking: Vec<(&str, f64)> =
        samples.iter().map(|s| (s.name.as_str(), s.min_s)).collect();
    ranking.sort_by(|a, b| a.1.partial_cmp(&b.1).unwrap());
    println!("\nranking (min step time):");
    for (name, t) in &ranking {
        println!("  {:<34} {:.2} ms", name, t * 1e3);
    }

    match write_json_report(REPORT_PATH, "perf_sweep", &samples) {
        Ok(()) => println!("\nwrote {REPORT_PATH}"),
        Err(e) => eprintln!("\ncould not write {REPORT_PATH}: {e}"),
    }
    Ok(())
}
