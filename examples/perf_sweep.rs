//! §Perf harness: measure per-step execute time of the sss_step variants
//! lowered by `python -m compile.perf_variants` (Pallas row-block B ×
//! backward chunk C) and print the ranking. Drives the L1/L2 rows of
//! EXPERIMENTS.md §Perf.

use shufflesort::bench::bench;
use shufflesort::runtime::{Arg, Runtime};

fn main() -> anyhow::Result<()> {
    let dir = std::env::args().nth(1).unwrap_or_else(|| "artifacts_perf".into());
    let rt = Runtime::from_manifest(&dir)?;
    let names = rt.artifact_names();
    println!("{} variants in {dir}", names.len());

    let mut results: Vec<(String, f64)> = Vec::new();
    for name in names {
        let exe = rt.load(&name)?;
        let n = exe.meta.n;
        let d = exe.meta.d;
        let w: Vec<f32> = (0..n).map(|i| (n - i) as f32).collect();
        let x: Vec<f32> = (0..n * d).map(|i| ((i * 2654435761) % 1000) as f32 / 1000.0).collect();
        let inv: Vec<i32> = (0..n as i32).collect();
        let s = bench(&name, 3, 15, || {
            exe.run(&[
                Arg::F32(&w),
                Arg::F32(&x),
                Arg::I32(&inv),
                Arg::ScalarF32(0.3),
                Arg::ScalarF32(0.5),
            ])
            .unwrap()
        });
        println!("{}", s.line());
        results.push((s.name, s.min_s));
    }
    results.sort_by(|a, b| a.1.partial_cmp(&b.1).unwrap());
    println!("\nranking (min step time):");
    for (name, t) in &results {
        println!("  {:<34} {:.2} ms", name, t * 1e3);
    }
    Ok(())
}
