//! END-TO-END DRIVER (DESIGN.md §10, Fig. 6): the full Self-Organizing-
//! Gaussians pipeline on a real (synthetic-scene) workload, exercising all
//! three layers:
//!
//!   scene (Rust substrate) → ShuffleSoftSort (Rust coordinator → PJRT →
//!   AOT HLO containing the Pallas kernel) → attribute-plane codec (Rust)
//!   → compression ratio + PSNR vs the shuffled and heuristic baselines.
//!
//! Results are recorded in EXPERIMENTS.md §E6. Pass `--full` for the
//! 4096-splat paper-scale run (several minutes on one core); default is a
//! 1024-splat run (~1 minute).

use anyhow::Result;

use shufflesort::api::{overrides, Engine};
use shufflesort::grid::GridShape;
use shufflesort::metrics::corr::mean_lag1_autocorr;
use shufflesort::sog::codec::CodecConfig;
use shufflesort::sog::scene::{GaussianScene, SceneConfig, ATTR_DIM};
use shufflesort::sog::{run_pipeline, SorterKind};

fn main() -> Result<()> {
    let full = std::env::args().any(|a| a == "--full");
    let (n, phases) = if full { (4096, 16384) } else { (1024, 8192) };
    let side = (n as f64).sqrt() as usize;
    let g = GridShape::new(side, side);

    println!("=== Self-Organizing Gaussians end-to-end ({n} splats, {side}x{side} grid) ===");
    let scene = GaussianScene::generate(&SceneConfig {
        n_splats: n,
        seed: 7,
        ..Default::default()
    });
    let (norm, _) = scene.normalized();
    println!(
        "scene: {} attributes/splat, raw {} bytes, shuffled-order lag-1 corr {:.3}",
        ATTR_DIM,
        n * ATTR_DIM * 4,
        mean_lag1_autocorr(&norm, ATTR_DIM, g)
    );

    let codec = CodecConfig::default(); // 8-bit, adaptive range coder
    let engine = Engine::builder("artifacts").build();

    // Baseline 1: no sorting (what a raw export compresses to).
    let shuffled = run_pipeline(&scene, g, SorterKind::Shuffled, &codec)?;
    println!("{}", shuffled.summary());

    // Baseline 2: heuristic sorting (original SOG uses a non-learned sorter).
    let flas = engine.sorter("flas", &overrides(&[("seed", "11")]))?;
    let heuristic = run_pipeline(&scene, g, SorterKind::Sorter(flas.as_ref()), &codec)?;
    println!("{}", heuristic.summary());

    // The paper's contribution: gradient-based sorting with N parameters.
    // record_curve=false keeps memory flat on the long run.
    let phases = phases.to_string();
    let sss = engine.sorter(
        "shuffle-softsort",
        &overrides(&[("phases", phases.as_str()), ("record_curve", "false")]),
    )?;
    let learned = run_pipeline(&scene, g, SorterKind::Sorter(sss.as_ref()), &codec)?;
    println!("{}", learned.summary());

    println!("\n--- Fig. 6 reproduction summary ---");
    for r in [&shuffled, &heuristic, &learned] {
        println!(
            "{:<12} ratio={:>5.2}x corr={:>6.3} psnr={:>5.1}dB",
            r.label, r.ratio, r.spatial_corr, r.mean_psnr_db
        );
    }
    let gain = shuffled.compressed_bytes as f64 / learned.compressed_bytes as f64;
    println!(
        "\nlearned sorting stores the same scene in {:.1}% of the shuffled-order size ({gain:.2}x denser)",
        100.0 / gain
    );
    println!(
        "memory for permutation learning: {} parameters (Gumbel-Sinkhorn would need {})",
        n,
        n * n
    );
    Ok(())
}
