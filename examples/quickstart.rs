//! Quickstart: sort 256 random RGB colors onto a 16×16 grid with
//! ShuffleSoftSort and report the quality metrics.
//!
//! Run (after `make artifacts && cargo build --release`):
//!
//! ```sh
//! cargo run --release --offline --example quickstart
//! ```

use shufflesort::prelude::*;
use shufflesort::metrics::mean_neighbor_distance;
use shufflesort::util::ppm;

fn main() -> anyhow::Result<()> {
    // 1. Load the AOT artifacts (HLO text, compiled once per process).
    let rt = Runtime::from_manifest("artifacts")?;
    println!("PJRT platform: {}", rt.platform());

    // 2. A workload: 256 random RGB colors on a 16×16 grid.
    let data = shufflesort::data::random_colors(256, 42);
    let g = GridShape::new(16, 16);
    println!(
        "unsorted: neighbor-dist={:.4}  DPQ16={:.3}",
        mean_neighbor_distance(&data.rows, data.d, g),
        dpq(&data.rows, data.d, g, 16.0, 16)
    );

    // 3. Sort with the paper's method (Algorithm 1). `for_grid` picks the
    //    tuned defaults; everything is overridable (see `sssort help`).
    let mut cfg = ShuffleSoftSortConfig::for_grid(16, 16);
    cfg.phases = 2048; // quickstart budget: a few seconds
    let sorter = ShuffleSoftSort::new(&rt, cfg)?;
    let out: SortOutcome = sorter.sort(&data)?;

    // 4. Inspect the result.
    println!("{}", out.report.summary());
    println!(
        "sorted:   neighbor-dist={:.4}  DPQ16={:.3}  ({} phases rejected by greedy accept)",
        mean_neighbor_distance(&out.arranged, data.d, g),
        out.report.final_dpq,
        out.report.rejected_phases,
    );

    // 5. The permutation maps grid cells to original item indices and the
    //    loss curve is recorded for plotting.
    let p = out.perm.as_slice();
    println!("perm[0..8] = {:?}", &p[..8]);
    let (first, last) = out.report.loss_span();
    println!("loss: {first:.4} -> {last:.4} over {} steps", out.report.steps);

    // 6. Save a viewable image of the sorted grid.
    std::fs::create_dir_all("out")?;
    ppm::write_ppm_upscaled(std::path::Path::new("out/quickstart.ppm"), &out.arranged, 16, 16, 16)?;
    println!("wrote out/quickstart.ppm");
    Ok(())
}
