//! Quickstart: sort 256 random RGB colors onto a 16×16 grid with
//! ShuffleSoftSort through the unified `Engine`/registry API and report
//! the quality metrics.
//!
//! Works on a bare checkout: the default `auto` backend uses the AOT
//! artifacts when `artifacts/manifest.json` exists and otherwise runs the
//! pure-Rust native backend — no `make artifacts` required.
//!
//! ```sh
//! cargo run --release --offline --example quickstart
//! ```

use shufflesort::api::overrides;
use shufflesort::metrics::mean_neighbor_distance;
use shufflesort::prelude::*;
use shufflesort::util::ppm;

fn main() -> anyhow::Result<()> {
    // 1. Open a session. The Engine resolves the compute backend (`auto`:
    //    prefer artifacts when present, else pure-Rust native) and owns the
    //    method registry. Force one with .backend(BackendChoice::Native).
    let engine = Engine::builder("artifacts").build();
    println!("backend: {}", engine.backend_desc(&[])?);
    println!("methods: {}", engine.registry().names().join(", "));

    // 2. A workload: 256 random RGB colors on a 16×16 grid.
    let data = shufflesort::data::random_colors(256, 42);
    let g = GridShape::new(16, 16);
    println!(
        "unsorted: neighbor-dist={:.4}  DPQ16={:.3}",
        mean_neighbor_distance(&data.rows, data.d, g),
        dpq(&data.rows, data.d, g, 16.0, 16)
    );

    // 3. Sort with the paper's method (Algorithm 1). Any registry name
    //    works here — try "flas" or "som" for the heuristics. Defaults are
    //    tuned per grid; `k=v` overrides tweak them (same pairs as
    //    `sssort sort ... phases=2048`, including `backend=native`).
    let out: SortOutcome = engine.sort(
        "shuffle-softsort",
        &data,
        g,
        &overrides(&[("phases", "2048")]), // quickstart budget: a few seconds
    )?;

    // 4. Inspect the result.
    println!("{}", out.report.summary());
    println!(
        "sorted:   neighbor-dist={:.4}  DPQ16={:.3}  ({} phases rejected by greedy accept)",
        mean_neighbor_distance(&out.arranged, data.d, g),
        out.report.final_dpq,
        out.report.rejected_phases,
    );

    // 5. The permutation maps grid cells to original item indices and the
    //    loss curve is recorded for plotting.
    let p = out.perm.as_slice();
    println!("perm[0..8] = {:?}", &p[..8]);
    let (first, last) = out.report.loss_span();
    println!("loss: {first:.4} -> {last:.4} over {} steps", out.report.steps);

    // 6. Save a viewable image of the sorted grid.
    std::fs::create_dir_all("out")?;
    ppm::write_ppm_upscaled(std::path::Path::new("out/quickstart.ppm"), &out.arranged, 16, 16, 16)?;
    println!("wrote out/quickstart.ppm");

    // 7. Batching: many datasets across worker threads, one call. Results
    //    are bit-identical to sequential `sort` calls (the native backend
    //    is shared by all workers; PJRT builds one runtime per worker).
    let batch: Vec<Dataset> = (0..4).map(|s| shufflesort::data::random_colors(256, s)).collect();
    for (i, result) in engine
        .sort_batch("shuffle-softsort", &batch, g, &overrides(&[("phases", "512")]))
        .into_iter()
        .enumerate()
    {
        println!("batch[{i}]: {}", result?.report.summary());
    }
    Ok(())
}
