//! Sorting zoo: every sorter in the crate — four learned methods (via the
//! PJRT runtime) and four heuristic/classical baselines — on the same
//! random-color workload, with DPQ₁₆ and runtime side by side.

use anyhow::Result;

use shufflesort::config::{BaselineConfig, ShuffleSoftSortConfig};
use shufflesort::coordinator::baselines::{
    GumbelSinkhornDriver, KissingDriver, SoftSortDriver,
};
use shufflesort::coordinator::ShuffleSoftSort;
use shufflesort::data::random_colors;
use shufflesort::dimred::DrLap;
use shufflesort::grid::GridShape;
use shufflesort::heuristics::{flas::Flas, som::Som, ssm::Ssm, GridSorter};
use shufflesort::metrics::dpq16;
use shufflesort::runtime::Runtime;
use shufflesort::util::timer::Stopwatch;

fn main() -> Result<()> {
    let (h, w) = (16usize, 16usize);
    let n = h * w;
    let g = GridShape::new(h, w);
    let ds = random_colors(n, 42);
    println!("workload: {n} random RGB colors on {h}x{w}");
    println!("{:<18} {:>8} {:>8} {:>9}", "method", "dpq16", "secs", "params");
    println!("{:-<18} {:->8} {:->8} {:->9}", "", "", "", "");
    println!("{:<18} {:>8.3} {:>8} {:>9}", "unsorted", dpq16(&ds.rows, 3, g), "-", "-");

    // Heuristics (pure Rust).
    let sorters: Vec<Box<dyn GridSorter>> = vec![
        Box::new(Som::default()),
        Box::new(Ssm::default()),
        Box::new(Flas::default()),
        Box::new(Flas::las(24)),
        Box::new(DrLap { use_tsne: false }),
        Box::new(DrLap { use_tsne: true }),
    ];
    for s in sorters {
        let t = Stopwatch::start();
        let p = s.sort(&ds.rows, 3, g, 7);
        let secs = t.secs();
        let q = dpq16(&p.apply_rows(&ds.rows, 3), 3, g);
        println!("{:<18} {:>8.3} {:>8.2} {:>9}", s.name(), q, secs, "-");
    }

    // Learned methods (PJRT runtime).
    let rt = Runtime::from_manifest("artifacts")?;
    {
        let mut cfg = ShuffleSoftSortConfig::for_grid(h, w);
        cfg.phases = 4096;
        let out = ShuffleSoftSort::new(&rt, cfg)?.sort(&ds)?;
        println!(
            "{:<18} {:>8.3} {:>8.2} {:>9}",
            "ShuffleSoftSort", out.report.final_dpq, out.report.wall_secs, out.report.param_count
        );
    }
    {
        let mut cfg = BaselineConfig::for_grid(h, w);
        cfg.steps = 4096;
        let out = SoftSortDriver::new(&rt, cfg).sort(&ds)?;
        println!(
            "{:<18} {:>8.3} {:>8.2} {:>9}",
            "SoftSort", out.report.final_dpq, out.report.wall_secs, out.report.param_count
        );
    }
    {
        let mut cfg = BaselineConfig::for_gs(h, w);
        cfg.steps = 2048;
        let out = GumbelSinkhornDriver::new(&rt, cfg).sort(&ds)?;
        println!(
            "{:<18} {:>8.3} {:>8.2} {:>9}",
            "Gumbel-Sinkhorn", out.report.final_dpq, out.report.wall_secs, out.report.param_count
        );
    }
    {
        let mut cfg = BaselineConfig::for_grid(h, w);
        cfg.steps = 2048;
        let out = KissingDriver::new(&rt, cfg).sort(&ds)?;
        println!(
            "{:<18} {:>8.3} {:>8.2} {:>9}  (valid={})",
            "Kissing",
            out.report.final_dpq,
            out.report.wall_secs,
            out.report.param_count,
            out.report.valid_without_repair
        );
    }
    Ok(())
}
