//! Sorting zoo: every sorter in the registry — four learned methods (on
//! the engine's resolved backend: PJRT artifacts when present, else the
//! pure-Rust native backend) and six heuristic/classical baselines — on
//! the same random-color workload, with DPQ₁₆ and runtime side by side.
//! The whole sweep is registry-driven: adding a method to
//! `api::MethodRegistry` automatically adds a row here.

use anyhow::Result;

use shufflesort::api::{overrides, Engine, MethodKind};
use shufflesort::data::random_colors;
use shufflesort::grid::GridShape;
use shufflesort::metrics::dpq16;

fn main() -> Result<()> {
    let (h, w) = (16usize, 16usize);
    let n = h * w;
    let g = GridShape::new(h, w);
    let ds = random_colors(n, 42);
    let engine = Engine::builder("artifacts").build();
    println!("workload: {n} random RGB colors on {h}x{w}");
    println!("{:<18} {:>8} {:>8} {:>9}", "method", "dpq16", "secs", "params");
    println!("{:-<18} {:->8} {:->8} {:->9}", "", "", "", "");
    println!("{:<18} {:>8.3} {:>8} {:>9}", "unsorted", dpq16(&ds.rows, 3, g), "-", "-");

    // Heuristics (pure Rust — no artifacts needed).
    for spec in engine.registry().specs().iter().filter(|s| s.kind == MethodKind::Heuristic) {
        let out = engine.sort(spec.name, &ds, g, &overrides(&[("seed", "7")]))?;
        println!(
            "{:<18} {:>8.3} {:>8.2} {:>9}",
            spec.name, out.report.final_dpq, out.report.wall_secs, "-"
        );
    }

    // Learned methods (resolved backend; budgets comparable across methods).
    let learned: &[(&str, &[(&str, &str)])] = &[
        ("shuffle-softsort", &[("phases", "4096")]),
        ("softsort", &[("steps", "4096")]),
        ("gumbel-sinkhorn", &[("steps", "2048")]),
        ("kissing", &[("steps", "2048")]),
    ];
    for &(name, ov) in learned {
        let out = engine.sort(name, &ds, g, &overrides(ov))?;
        let valid = if out.report.valid_without_repair { "" } else { "  (repaired)" };
        println!(
            "{:<18} {:>8.3} {:>8.2} {:>9}{valid}",
            name, out.report.final_dpq, out.report.wall_secs, out.report.param_count
        );
    }
    Ok(())
}
