//! Grid-based image sorting (paper §IV-A, Fig. 5): arrange a synthetic
//! "e-commerce catalogue" of 50-dimensional visual feature vectors so that
//! similar items sit together — the workload the paper motivates for stock
//! agencies and shops. The proprietary image set is substituted with
//! clustered synthetic features (DESIGN.md §3); the measured quantity is
//! the same: layout quality (DPQ) + cluster spatial coherence.

use anyhow::Result;

use shufflesort::api::{overrides, Engine};
use shufflesort::data::clustered_features;
use shufflesort::grid::GridShape;
use shufflesort::metrics::{dpq16, mean_neighbor_distance};
use shufflesort::perm::Permutation;
use shufflesort::util::ppm;

/// Fraction of horizontally/vertically adjacent cell pairs whose items
/// share a ground-truth cluster — "do same-category products sit together".
fn cluster_coherence(perm: &Permutation, labels: &[u32], g: GridShape) -> f64 {
    let pairs = g.neighbor_pairs();
    let same = pairs
        .iter()
        .filter(|&&(a, b)| {
            labels[perm.as_slice()[a as usize] as usize]
                == labels[perm.as_slice()[b as usize] as usize]
        })
        .count();
    same as f64 / pairs.len() as f64
}

/// Render clusters as distinct hues for a quick visual (PPM).
fn label_image(perm: &Permutation, labels: &[u32], k: usize, g: GridShape) -> Vec<f32> {
    let mut img = vec![0.0f32; g.n() * 3];
    for cell in 0..g.n() {
        let l = labels[perm.as_slice()[cell] as usize] as f32 / k as f32;
        let hue = l * 6.0;
        let (r, gg, b) = match hue as usize {
            0 => (1.0, hue.fract(), 0.0),
            1 => (1.0 - hue.fract(), 1.0, 0.0),
            2 => (0.0, 1.0, hue.fract()),
            3 => (0.0, 1.0 - hue.fract(), 1.0),
            4 => (hue.fract(), 0.0, 1.0),
            _ => (1.0, 0.0, 1.0 - hue.fract()),
        };
        img[cell * 3] = r;
        img[cell * 3 + 1] = gg;
        img[cell * 3 + 2] = b;
    }
    img
}

fn main() -> Result<()> {
    let (h, w, k) = (16usize, 16usize, 12usize);
    let n = h * w;
    let g = GridShape::new(h, w);
    let data = clustered_features(n, 50, k, 0.06, 7);
    let labels = data.labels.clone().expect("generator provides labels");

    println!("image-sort workload: {n} items, 50-d features, {k} clusters");
    println!(
        "unsorted: dpq={:.3} nbr={:.4} coherence={:.3}",
        dpq16(&data.rows, data.d, g),
        mean_neighbor_distance(&data.rows, data.d, g),
        cluster_coherence(&Permutation::identity(n), &labels, g)
    );

    // One session for both methods; the runtime loads lazily, so FLAS runs
    // even before `make artifacts`.
    let engine = Engine::builder("artifacts").build();

    // Heuristic reference (what a production system uses today).
    let flas = engine.sort("flas", &data, g, &overrides(&[("seed", "3")]))?;
    println!(
        "FLAS:     dpq={:.3} coherence={:.3}",
        flas.report.final_dpq,
        cluster_coherence(&flas.perm, &labels, g)
    );

    // The paper's method.
    let out = engine.sort(
        "shuffle-softsort",
        &data,
        g,
        &overrides(&[("phases", "3072")]),
    )?;
    println!(
        "ShuffleSoftSort: dpq={:.3} coherence={:.3} ({:.1}s, {} params)",
        out.report.final_dpq,
        cluster_coherence(&out.perm, &labels, g),
        out.report.wall_secs,
        out.report.param_count
    );

    std::fs::create_dir_all("out")?;
    ppm::write_ppm_upscaled(
        std::path::Path::new("out/image_sort_clusters.ppm"),
        &label_image(&out.perm, &labels, k, g),
        h,
        w,
        16,
    )?;
    println!("wrote out/image_sort_clusters.ppm (clusters as hues)");
    Ok(())
}
